//! `skein` — the Skeinformer coordinator CLI.
//!
//! Subcommands:
//!   train    — train one (method, task) experiment via the AOT artifacts
//!   sweep    — run a method × task sweep and print Tables 1-3
//!   fig1     — the Figure-1 spectral-norm approximation study
//!   flops    — print the Table-5 FLOPs model
//!   serve    — run the batched inference service demo (or, with
//!              --listen ADDR, a TCP serving front end)
//!   coordinator — front a cluster of `serve --listen` engine shards:
//!              scatter head ranges, gather replies, same wire protocol
//!   client   — drive a `serve --listen` (or coordinator) front end over TCP
//!   top      — live terminal view of a server/coordinator's stats reply
//!   scrape   — fetch a `/metrics` endpoint and validate the exposition
//!   inspect  — dump an artifact manifest summary
//!
//! Run `skein help` for flags.

use anyhow::{bail, Context, Result};
use skeinformer::{
    attention, bench_util, cli::Args, config::ExperimentConfig, coordinator, data, flops, json,
    obs, report, rng::Rng, runtime::Runtime, synth_qkv, tensor, train,
};
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    // Global flag: size of the persistent worker pool every parallel path
    // (matmul row blocks, batched head dispatch) executes on.  0 keeps
    // the default (logical CPUs, capped at 16).
    let pool_size = args.get_usize("pool-size", 0)?;
    if pool_size > 0 {
        skeinformer::pool::set_pool_size(pool_size);
    }
    // Global flag: pin the microkernel ISA (overrides SKEIN_KERNEL and
    // runtime detection).  Errors rather than degrading silently — a
    // pin exists to be trusted.
    if let Some(k) = args.get("kernel") {
        let isa = tensor::kernels::KernelIsa::parse(k)
            .ok_or_else(|| anyhow::anyhow!("--kernel {k:?} unrecognised (want avx2|sse2|scalar)"))?;
        tensor::kernels::select(isa).map_err(|e| anyhow::anyhow!(e))?;
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("fig1") => cmd_fig1(&args),
        Some("flops") => cmd_flops(&args),
        Some("serve") => cmd_serve(&args),
        Some("coordinator") => cmd_coordinator(&args),
        Some("client") => cmd_client(&args),
        Some("top") => cmd_top(&args),
        Some("scrape") => cmd_scrape(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} — try `skein help`"),
    }
}

fn print_help() {
    println!(
        "skein {} — Skeinformer (NAACL 2022) reproduction\n\n\
         USAGE: skein <subcommand> [--flags]\n\n\
         SUBCOMMANDS\n\
           train    --method skeinformer --task listops [--steps N] [--eval-every N]\n\
           sweep    --methods a,b,c --tasks x,y [--steps N]\n\
           fig1     [--n 1024] [--trials 8] [--mode pretrained|random]\n\
           flops    [--n 4096] [--d 256] [--p 32]\n\
           serve    --method skeinformer [--engine cpu|pjrt] [--requests N] [--max-wait-ms N]\n\
                    cpu engine (default; batched attention, no artifacts needed):\n\
                    [--batch B] [--heads H] [--seq N] [--head-dim P] [--d D] [--workers W]\n\
                    [--kv-batch-dedupe] (route one-shot request K/V slabs through\n\
                    the paged cache: resubmitted/prompt-shared batches dedupe)\n\
                    --stream runs a streaming-decode demo instead (one token\n\
                    appended + queried per step): [--tokens N] [--repilot-stride S]\n\
                    [--streams S] [--prefill-chunk C] (ingest the prompt via\n\
                    chunked Prefill ops of C tokens + one final query, instead\n\
                    of per-token decode; 0 = off) paged KV cache: [--kv-blocks N]\n\
                    (capacity; enables the cache) [--kv-window W] (sliding\n\
                    window, tokens) [--kv-block-size B] (tokens/block, default 16)\n\
                    [--kv-tiers f16,int8] (demote cold blocks under pressure\n\
                    instead of dropping them) [--kv-spill-dir PATH] (spill\n\
                    exact bytes to a content-addressed store; warm restarts)\n\
                    --listen ADDR serves the same engine over TCP instead of\n\
                    running the demo loop (e.g. --listen 127.0.0.1:7878;\n\
                    [--serve-secs N] stops after N seconds, default: forever;\n\
                    [--queue-depth N] bounds in-flight work;\n\
                    [--shard-of N --shard-index I] annotate this worker as\n\
                    shard I of an N-shard ring for a coordinator)\n\
                    telemetry (on by default for --listen):\n\
                    [--metrics-addr H:P] Prometheus text exposition over\n\
                    HTTP GET /metrics; [--trace-out FILE] write the span\n\
                    flight recorder as Chrome-trace JSON at shutdown;\n\
                    [--stats-every-secs N] periodic stats line on stderr;\n\
                    [--no-telemetry] kill switch (serving is bitwise\n\
                    identical either way; spans read clocks only)\n\
           coordinator --shards H1:P1,H2:P2,... --listen ADDR\n\
                    front a cluster of `serve --listen` engine shards on the\n\
                    same wire protocol: one-shots scatter by head range and\n\
                    gather bitwise, decode streams home by prompt-prefix\n\
                    consistent hashing; [--heartbeat-ms N] failover cadence\n\
                    (default 1000); [--serve-secs N] as for serve.  Shards\n\
                    must share shape and --seed (checked at connect).\n\
                    Same telemetry flags as serve --listen; its stats reply\n\
                    aggregates the cluster (histograms merged bucket-wise,\n\
                    gauges summed) plus per-shard health rows\n\
           client   --addr HOST:PORT [--requests N] [--window W] (pipelined\n\
                    one-shot submits, W in flight), or\n\
                    --stream [--tokens N] [--repilot-stride S] (decode loop);\n\
                    workload shape comes from the server's handshake\n\
           top      --addr HOST:PORT [--interval-ms N] [--iterations N]\n\
                    live terminal view of a server or coordinator: engine\n\
                    counters, span histogram percentiles, shard health\n\
                    (0 iterations = refresh until killed)\n\
           scrape   --addr HOST:PORT fetch /metrics once and validate the\n\
                    exposition is well-formed (nonzero exit otherwise)\n\
           inspect  <artifacts/..._manifest.json>\n\n\
         GLOBAL FLAGS\n\
           --pool-size N   worker threads in the persistent pool (default:\n\
                           logical CPUs, capped at 16; 0 = default)\n\
           --kernel ISA    pin the SIMD microkernel tier: avx2|sse2|scalar\n\
                           (default: SKEIN_KERNEL env, else widest the\n\
                           build/CPU supports; every tier is bitwise\n\
                           identical — this is a speed knob only)\n\n\
         Artifacts come from `make artifacts` (python AOT path); `serve\n\
         --engine pjrt` additionally needs the real xla crate (not the\n\
         offline stub) linked in.",
        skeinformer::version()
    );
}

/// Millisecond view of one latency-histogram percentile.  The CLI demo
/// loops record into constant-memory [`obs::Histo`]s (log2 buckets), so
/// reported percentiles are bucket upper bounds, not exact samples —
/// the trade that lets a server report latency forever without
/// retaining per-request samples.
fn histo_ms(snap: &obs::HistoSnapshot, p: f64) -> f64 {
    snap.percentile(p) as f64 / 1e6
}

fn base_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    cfg.method = args.get_or("method", "skeinformer").to_string();
    cfg.task = args.get_or("task", "listops").to_string();
    cfg.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    cfg.train.max_steps = args.get_usize("steps", cfg.train.max_steps)?;
    cfg.train.eval_every = args.get_usize("eval-every", cfg.train.eval_every)?;
    cfg.train.patience = args.get_usize("patience", cfg.train.patience)?;
    cfg.train.seed = args.get_u64("seed", cfg.train.seed)?;
    cfg.train.eval_examples = args.get_usize("eval-examples", cfg.train.eval_examples)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let rt = Runtime::cpu()?;
    eprintln!("training {} on {} (artifacts: {})", cfg.method, cfg.task, cfg.artifacts_dir);
    let outcome = train::run_experiment(&rt, &cfg)?;
    println!(
        "method={} task={} steps={} best_acc={:.4} final_acc={:.4} time={:.1}s ms/step={:.1}",
        outcome.method,
        outcome.task,
        outcome.steps,
        outcome.best_accuracy,
        outcome.final_accuracy,
        outcome.seconds,
        outcome.ms_per_step
    );
    for p in outcome.history.points() {
        println!(
            "  step {:>5}  t={:>7.1}s  train_loss={:.4}  val_loss={:.4}  val_acc={:.4}",
            p.step, p.seconds, p.train_loss, p.val_loss, p.val_accuracy
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let methods = args
        .get_list("methods")
        .unwrap_or_else(|| vec!["skeinformer".into(), "standard".into()]);
    let tasks = args.get_list("tasks").unwrap_or_else(|| vec!["listops".into()]);
    let sweep = coordinator::Sweep { methods, tasks, base: cfg };
    let outcomes = coordinator::run_sweep(&sweep, true)?;
    println!("\n=== Table 1 (accuracy %) ===\n{}", report::table1(&outcomes));
    println!("=== Table 2 (steps / ms-per-step / accum) ===\n{}", report::table2(&outcomes));
    println!("=== Table 3 (total steps / seconds) ===\n{}", report::table3(&outcomes));
    println!("=== Paper vs measured ===\n{}", report::paper_vs_measured(&outcomes));
    let (header, rows) = report::figure2_csv(&outcomes);
    bench_util::write_csv("reports/figure2_sweep.csv", &header, &rows)?;
    eprintln!("figure-2 series written to reports/figure2_sweep.csv");
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 1024)?;
    let p = args.get_usize("p", 64)?;
    let trials = args.get_usize("trials", 8)?;
    let mode = args.get_or("mode", "pretrained");
    let seed = args.get_u64("seed", 0)?;
    let cfg = match mode {
        "pretrained" => synth_qkv::QkvConfig::pretrained(n, p),
        "random" => synth_qkv::QkvConfig::random_init(n, p),
        other => bail!("unknown mode {other:?}"),
    };
    println!("Figure 1: spectral-norm loss, n={n} p={p} mode={mode} trials={trials}");
    let mut rng = Rng::new(seed);
    let (q, k, v) = synth_qkv::generate(&cfg, &mut rng);
    let exact = attention::Standard::exact(&q, &k, &v, None);
    let base = tensor::spectral_norm(&exact);
    let ds: Vec<usize> = (3..=8).map(|e| 1usize << e).collect();
    let mut rows = Vec::new();
    for &d in &ds {
        for method in attention::registry(d) {
            if method.is_exact() {
                continue;
            }
            let mut stats = skeinformer::metrics::RunningStats::new();
            for t in 0..trials {
                let out = method.compute(&q, &k, &v, None, &mut Rng::new(seed + 1 + t as u64));
                stats.push((tensor::spectral_norm_diff(&out, &exact) / base) as f64);
            }
            println!(
                "  d={d:<4} {:<20} loss={:.4} ± {:.4}",
                method.name(),
                stats.mean(),
                stats.std_err()
            );
            rows.push(format!(
                "{},{},{},{:.6},{:.6}",
                mode,
                d,
                method.name(),
                stats.mean(),
                stats.std_err()
            ));
        }
    }
    bench_util::write_csv(
        &format!("reports/figure1_n{n}_{mode}.csv"),
        "mode,d,method,rel_spectral_loss,std_err",
        &rows,
    )?;
    Ok(())
}

fn cmd_flops(args: &Args) -> Result<()> {
    let n = args.get_u64("n", 4096)?;
    let d = args.get_u64("d", 256)?;
    let p = args.get_u64("p", 32)?;
    println!("Table 5: leading-term attention FLOPs at n={n}, d={d}, p={p}");
    let mut rows = Vec::new();
    for m in skeinformer::config::KNOWN_METHODS {
        let sym = flops::leading_flops_symbolic(m).unwrap_or("-");
        match flops::leading_flops(m, n, d, p) {
            Some(fl) => {
                rows.push(vec![m.to_string(), sym.into(), format!("{:.3}G", fl as f64 / 1e9)])
            }
            None => rows.push(vec![m.to_string(), sym.into(), "input-dependent".into()]),
        }
    }
    println!("{}", bench_util::ascii_table(&["Model", "Leading term", "FLOPs"], &rows));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Default to the pure-rust engine: it is always available, whereas
    // artifacts on disk do not imply PJRT is executable (offline builds
    // link the stub xla crate).  `--engine pjrt` opts into the AOT path.
    match args.get_or("engine", "cpu") {
        "cpu" => cmd_serve_cpu(args),
        "pjrt" => cmd_serve_pjrt(args),
        other => bail!("unknown engine {other:?} — expected cpu or pjrt"),
    }
}

/// Serve raw Q/K/V head slabs through the batched attention engine: the
/// B×H workload shape (`--batch`, `--heads`) the throughput benches use.
/// `--stream` switches to the autoregressive-decode demo instead.
fn cmd_serve_cpu(args: &Args) -> Result<()> {
    use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};

    let cfg = AttentionServerConfig::from_args(args)?;
    if let Some(listen) = args.get("listen") {
        return cmd_serve_listen(args, cfg, listen);
    }
    if args.switch("stream") {
        return cmd_serve_stream(args, cfg);
    }
    let n_requests = args.get_usize("requests", 64)?;
    eprintln!(
        "batched attention service: method={} B<={} H={} n={} p={} d={} kernel={}",
        cfg.method,
        cfg.max_batch,
        cfg.heads,
        cfg.seq,
        cfg.head_dim,
        cfg.d,
        tensor::kernels::active_isa()
    );

    let handle = attention_server::start(cfg.clone())?;
    let mut rng = Rng::new(7);
    let elems = cfg.request_elems();
    let latency = obs::Histo::default();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..n_requests {
        let req = HeadsRequest::random(elems, &mut rng);
        pending.push((handle.submit(req), std::time::Instant::now()));
    }
    for (rx, sent) in pending {
        let out = rx.recv().context("server dropped request")?;
        latency.record(sent.elapsed().as_nanos() as u64);
        anyhow::ensure!(out.len() == elems);
        anyhow::ensure!(out.iter().all(|x| x.is_finite()));
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown()?;
    println!(
        "served {} sequences in {:.2}s ({:.1} seq/s) — batches={} occupancy={:.2} \
         engine {:.1} ms/batch",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        stats.batches,
        stats.mean_occupancy,
        stats.mean_batch_ms
    );
    let snap = latency.snapshot();
    println!(
        "latency ms: p50={:.1} p95={:.1} p99={:.1} (queue {:.1})",
        histo_ms(&snap, 50.0),
        histo_ms(&snap, 95.0),
        histo_ms(&snap, 99.0),
        stats.mean_queue_ms
    );
    Ok(())
}

/// `serve --listen ADDR`: expose the batched attention engine over TCP
/// instead of running the in-process demo loop.  Wire connections are
/// just more scheduler lanes, so serving is bitwise identical to the
/// in-process path; `--serve-secs N` stops after N seconds (0 = run
/// until killed).
///
/// Telemetry is on by default here (`--no-telemetry` kills it):
/// `--metrics-addr H:P` exposes `GET /metrics`, `--trace-out FILE`
/// writes the span flight recorder as Chrome-trace JSON at shutdown,
/// and `--stats-every-secs N` prints a periodic stats line on stderr.
fn cmd_serve_listen(
    args: &Args,
    cfg: skeinformer::coordinator::attention_server::AttentionServerConfig,
    addr: &str,
) -> Result<()> {
    use skeinformer::coordinator::{attention_server, net};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let serve_secs = args.get_u64("serve-secs", 0)?;
    let shard_count = args.get_u64("shard-of", 0)? as u32;
    let shard_index = args.get_u64("shard-index", 0)? as u32;
    if shard_count > 0 && shard_index >= shard_count {
        bail!("--shard-index {shard_index} out of range for --shard-of {shard_count}");
    }
    let telemetry = obs::ServeTelemetry::new(!args.switch("no-telemetry"));
    let handle = attention_server::start_with_telemetry(cfg.clone(), Arc::clone(&telemetry))?;
    let backend = Arc::new(net::EngineBackend::new(&handle, shard_index, shard_count));
    let server = net::serve_backend(backend, addr).with_context(|| format!("bind {addr}"))?;
    let metrics = match args.get("metrics-addr") {
        Some(maddr) => {
            let conn = handle.connection();
            let t = Arc::clone(&telemetry);
            // engine counters first (the stats poll also refreshes the
            // KV residency gauges), then the registry exposition
            let render: obs::RenderFn = Arc::new(move || {
                let mut out = String::new();
                if let Some(s) = conn.stats() {
                    out.push_str(&attention_server::render_stats_prometheus(&s));
                }
                out.push_str(&t.render());
                out
            });
            let m = obs::serve_metrics(maddr, render)
                .with_context(|| format!("bind metrics endpoint {maddr}"))?;
            eprintln!("metrics on http://{}/metrics", m.local_addr());
            Some(m)
        }
        None => None,
    };
    let stats_stop = Arc::new(AtomicBool::new(false));
    let stats_join = spawn_stats_ticker(
        args.get_u64("stats-every-secs", 0)?,
        Arc::clone(&stats_stop),
        handle.connection(),
    );
    eprintln!(
        "serving method={} B<={} H={} n={} p={} kernel={}{} on {}{}{}",
        cfg.method,
        cfg.max_batch,
        cfg.heads,
        cfg.seq,
        cfg.head_dim,
        tensor::kernels::active_isa(),
        if shard_count > 0 {
            format!(" (shard {shard_index}/{shard_count})")
        } else {
            String::new()
        },
        server.local_addr(),
        if serve_secs > 0 { format!(" for {serve_secs}s") } else { " until killed".into() },
        if telemetry.enabled() { "" } else { " (telemetry off)" }
    );
    if serve_secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(serve_secs));
    server.stop();
    stats_stop.store(true, Ordering::SeqCst);
    if let Some(j) = stats_join {
        let _ = j.join();
    }
    if let Some(m) = metrics {
        m.stop();
    }
    let stats = handle.shutdown()?;
    println!(
        "served {} requests — steps={} step-occupancy={:.2} rejected={} \
         appends={} queries={} engine {:.1} ms/batch",
        stats.requests,
        stats.steps,
        stats.mean_step_occupancy,
        stats.rejected,
        stats.stream_appends,
        stats.stream_queries,
        stats.mean_batch_ms
    );
    write_trace_out(args, &telemetry, &cfg.method)?;
    Ok(())
}

/// `--stats-every-secs N`: a stderr stats line every N seconds until
/// `stop` (checked in short slices so shutdown is prompt).  Returns
/// `None` when `every_secs == 0` (off).
fn spawn_stats_ticker(
    every_secs: u64,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    conn: skeinformer::coordinator::attention_server::ServerConnection,
) -> Option<std::thread::JoinHandle<()>> {
    use std::sync::atomic::Ordering;
    if every_secs == 0 {
        return None;
    }
    Some(std::thread::spawn(move || {
        let slice = Duration::from_millis(250);
        let mut elapsed = Duration::ZERO;
        loop {
            std::thread::sleep(slice);
            if stop.load(Ordering::SeqCst) {
                return;
            }
            elapsed += slice;
            if elapsed < Duration::from_secs(every_secs) {
                continue;
            }
            elapsed = Duration::ZERO;
            let Some(s) = conn.stats() else { return };
            eprintln!(
                "stats: requests={} batches={} steps={} rejected={} appends={} queries={} \
                 occupancy={:.2} queue={:.1}ms batch={:.1}ms kv-resident={}",
                s.requests,
                s.batches,
                s.steps,
                s.rejected,
                s.stream_appends,
                s.stream_queries,
                s.mean_step_occupancy,
                s.mean_queue_ms,
                s.mean_batch_ms,
                s.kv_resident_blocks
            );
        }
    }))
}

/// `--trace-out FILE`: drain the flight recorder as Chrome-trace JSON
/// (load it at chrome://tracing or ui.perfetto.dev).
fn write_trace_out(args: &Args, telemetry: &obs::ServeTelemetry, method: &str) -> Result<()> {
    let Some(path) = args.get("trace-out") else { return Ok(()) };
    let rec = telemetry.recorder();
    std::fs::write(path, rec.to_chrome_trace(method))
        .with_context(|| format!("write trace {path}"))?;
    eprintln!(
        "chrome trace: {} span(s) ({} dropped oldest-first) written to {path}",
        rec.snapshot().len(),
        rec.dropped()
    );
    Ok(())
}

/// `skein coordinator --shards H1:P1,... --listen ADDR`: front a cluster
/// of `serve --listen` engine shards.  Clients connect to the
/// coordinator exactly as they would to a single worker; one-shot
/// requests scatter by head range (gathered bitwise), decode streams
/// home on shards by prompt-prefix consistent hashing, and dead shards
/// degrade to typed errors while the ring re-forms.  On a timed exit
/// the coordinator prints cluster-aggregated stats (counters summed,
/// means weighted per shard).
fn cmd_coordinator(args: &Args) -> Result<()> {
    use skeinformer::coordinator::{net, shard};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let shards = args
        .get_list("shards")
        .context("usage: skein coordinator --shards H1:P1,H2:P2,... --listen ADDR")?;
    let listen = args.get("listen").context("coordinator needs --listen ADDR")?;
    let heartbeat = Duration::from_millis(
        args.get_u64("heartbeat-ms", shard::DEFAULT_HEARTBEAT.as_millis() as u64)?.max(1),
    );
    let serve_secs = args.get_u64("serve-secs", 0)?;
    let telemetry = obs::ServeTelemetry::new(!args.switch("no-telemetry"));
    let coord = shard::Coordinator::start_with_telemetry(
        &shards,
        heartbeat,
        net::NetTimeouts::default(),
        Arc::clone(&telemetry),
    )?;
    let info = coord.info();
    let server = net::serve_backend(coord.backend(), listen)
        .with_context(|| format!("bind {listen}"))?;
    let metrics = match args.get("metrics-addr") {
        Some(maddr) => {
            // each scrape polls the shards through a fresh lane: merged
            // engine counters + the coordinator's own span histograms
            let backend = coord.backend();
            let t = Arc::clone(&telemetry);
            let render: obs::RenderFn = Arc::new(move || {
                let mut out = String::new();
                if let Some(sw) = backend.lane().stats() {
                    out.push_str(&skeinformer::coordinator::attention_server::render_stats_prometheus(&sw.stats));
                }
                out.push_str(&t.render());
                out
            });
            let m = obs::serve_metrics(maddr, render)
                .with_context(|| format!("bind metrics endpoint {maddr}"))?;
            eprintln!("metrics on http://{}/metrics", m.local_addr());
            Some(m)
        }
        None => None,
    };
    let stats_stop = Arc::new(AtomicBool::new(false));
    let stats_join = {
        let every_secs = args.get_u64("stats-every-secs", 0)?;
        (every_secs > 0).then(|| {
            let lane = coord.backend().lane();
            let stop = Arc::clone(&stats_stop);
            std::thread::spawn(move || {
                let slice = Duration::from_millis(250);
                let mut elapsed = Duration::ZERO;
                loop {
                    std::thread::sleep(slice);
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    elapsed += slice;
                    if elapsed < Duration::from_secs(every_secs) {
                        continue;
                    }
                    elapsed = Duration::ZERO;
                    let Some(sw) = lane.stats() else { return };
                    let live = sw.shards.iter().filter(|h| h.alive).count();
                    eprintln!(
                        "stats: shards={}/{} requests={} steps={} rejected={} appends={} \
                         queries={} queue={:.1}ms batch={:.1}ms",
                        live,
                        sw.shards.len(),
                        sw.stats.requests,
                        sw.stats.steps,
                        sw.stats.rejected,
                        sw.stats.stream_appends,
                        sw.stats.stream_queries,
                        sw.stats.mean_queue_ms,
                        sw.stats.mean_batch_ms
                    );
                }
            })
        })
    };
    eprintln!(
        "coordinating {} shard(s): method={} B<={} H={} n={} p={} seed={} kernel={} on {}{}{}",
        coord.live_shards(),
        info.method,
        info.max_batch,
        info.heads,
        info.seq,
        info.head_dim,
        info.seed,
        tensor::kernels::active_isa(),
        server.local_addr(),
        if serve_secs > 0 { format!(" for {serve_secs}s") } else { " until killed".into() },
        if telemetry.enabled() { "" } else { " (telemetry off)" }
    );
    if serve_secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(serve_secs));
    server.stop();
    stats_stop.store(true, Ordering::SeqCst);
    if let Some(j) = stats_join {
        let _ = j.join();
    }
    if let Some(m) = metrics {
        m.stop();
    }
    let live = coord.live_shards();
    let stats = coord.stats();
    let health = coord.shard_health();
    coord.shutdown();
    println!(
        "cluster served {} requests across {} live shard(s) — batches={} steps={} \
         step-occupancy={:.2} rejected={} appends={} queries={} engine {:.1} ms/batch \
         (queue {:.1} ms)",
        stats.requests,
        live,
        stats.batches,
        stats.steps,
        stats.mean_step_occupancy,
        stats.rejected,
        stats.stream_appends,
        stats.stream_queries,
        stats.mean_batch_ms,
        stats.mean_queue_ms
    );
    println!(
        "kv cache: hit-blocks={} alloc-blocks={} evicted={} resident={} ({:.1} KiB KV)",
        stats.kv_hit_blocks,
        stats.kv_alloc_blocks,
        stats.kv_evicted_blocks,
        stats.kv_resident_blocks,
        stats.kv_resident_bytes as f64 / 1024.0
    );
    if stats.kv_demoted_blocks + stats.kv_spilled_blocks + stats.kv_spill_hits > 0 {
        println!(
            "kv tiers: demoted={} spilled={} spill-hits={} spill-corrupt={}",
            stats.kv_demoted_blocks,
            stats.kv_spilled_blocks,
            stats.kv_spill_hits,
            stats.kv_spill_corrupt
        );
    }
    for h in &health {
        println!(
            "shard {}: {} heartbeat-age={}ms pending={} down-drains={}",
            h.addr,
            if h.alive { "live" } else { "dead" },
            h.heartbeat_age_ms,
            h.pending,
            h.down_drains
        );
    }
    write_trace_out(args, &telemetry, &info.method)?;
    Ok(())
}

/// `skein client --addr HOST:PORT`: drive a `serve --listen` front end.
/// The workload shape comes from the server's handshake.  Default mode
/// pipelines `--requests` one-shot submits with a bounded in-flight
/// `--window`; `--stream` runs a per-token decode loop instead
/// (`--tokens` append + one-row query steps).
fn cmd_client(args: &Args) -> Result<()> {
    use skeinformer::coordinator::attention_server::HeadsRequest;
    use skeinformer::coordinator::net::NetClient;

    let addr = args.get("addr").context("usage: skein client --addr HOST:PORT")?;
    let mut client = NetClient::connect(addr).with_context(|| format!("connect {addr}"))?;
    let info = client.info().clone();
    eprintln!(
        "connected to {addr}: method={} B<={} H={} n={} p={}",
        info.method, info.max_batch, info.heads, info.seq, info.head_dim
    );
    let mut rng = Rng::new(args.get_u64("seed", 7)?);
    let latency = obs::Histo::default();

    if args.switch("stream") {
        let tokens = args.get_usize("tokens", info.seq as usize)?;
        let stride = args.get_usize("repilot-stride", 1)? as u32;
        let token_elems = info.token_elems();
        let mut mk = |rng: &mut Rng| {
            let mut buf = vec![0.0f32; token_elems];
            rng.fill_normal(&mut buf);
            buf
        };
        let stream = client.open_stream(stride)?;
        let t0 = std::time::Instant::now();
        for _ in 0..tokens {
            let (k, v, q) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let step = std::time::Instant::now();
            client.append(stream, &k, &v)?;
            let out = client.query(stream, 1, &q)?;
            latency.record(step.elapsed().as_nanos() as u64);
            anyhow::ensure!(out.len() == token_elems);
            anyhow::ensure!(out.iter().all(|x| x.is_finite()));
        }
        client.close_stream(stream)?;
        let wall = t0.elapsed().as_secs_f64();
        println!("decoded {} tokens in {:.2}s ({:.1} tok/s)", tokens, wall, tokens as f64 / wall);
    } else {
        let n_requests = args.get_usize("requests", 64)?;
        let window = args.get_usize("window", 16)?.max(1);
        let elems = info.request_elems();
        let mut inflight = std::collections::VecDeque::new();
        let mut settle = |client: &mut NetClient,
                          inflight: &mut std::collections::VecDeque<(u64, std::time::Instant)>,
                          latency: &obs::Histo|
         -> Result<()> {
            let (id, sent) = inflight.pop_front().expect("settle on empty window");
            let out = client.wait_output(id)?;
            latency.record(sent.elapsed().as_nanos() as u64);
            anyhow::ensure!(out.len() == elems);
            anyhow::ensure!(out.iter().all(|x| x.is_finite()));
            Ok(())
        };
        let t0 = std::time::Instant::now();
        for _ in 0..n_requests {
            let req = HeadsRequest::random(elems, &mut rng);
            inflight.push_back((client.submit_async(&req)?, std::time::Instant::now()));
            // bounded pipeline: replies arrive in submission order on this
            // connection's lane, so draining the oldest keeps `window`
            // requests in flight without the server ever buffering more
            if inflight.len() >= window {
                settle(&mut client, &mut inflight, &latency)?;
            }
        }
        while !inflight.is_empty() {
            settle(&mut client, &mut inflight, &latency)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "submitted {} requests in {:.2}s ({:.1} seq/s, window {})",
            n_requests,
            wall,
            n_requests as f64 / wall,
            window
        );
    }
    let snap = latency.snapshot();
    println!(
        "latency ms: p50={:.2} p95={:.2} p99={:.2}",
        histo_ms(&snap, 50.0),
        histo_ms(&snap, 95.0),
        histo_ms(&snap, 99.0)
    );
    Ok(())
}

/// Streaming-decode demo: decode `--tokens` tokens per stream (append +
/// one-row query per step), report tokens/s and per-step latency
/// percentiles.  With `--streams S > 1` every stream replays the same
/// token sequence, so a KV-cache-enabled run (`--kv-blocks`) shows prefix
/// sharing: stream 1 allocates blocks, streams 2..S hit them.  With
/// `--prefill-chunk C > 0` the demo measures prompt *ingest* instead:
/// each stream's tokens go in as chunked `Prefill` ops of C tokens
/// (one channel message + per-block cache bookkeeping per chunk) followed
/// by a single one-row query.
fn cmd_serve_stream(
    args: &Args,
    cfg: skeinformer::coordinator::attention_server::AttentionServerConfig,
) -> Result<()> {
    use skeinformer::coordinator::attention_server;
    use std::sync::Arc;

    let tokens = args.get_usize("tokens", cfg.seq)?;
    let stride = args.get_usize("repilot-stride", 1)?;
    let n_streams = args.get_usize("streams", 1)?.max(1);
    let prefill_chunk = args.get_usize("prefill-chunk", 0)?;
    eprintln!(
        "streaming decode demo: method={} H={} p={} tokens={} repilot-stride={} streams={}{}{}",
        cfg.method,
        cfg.heads,
        cfg.head_dim,
        tokens,
        stride,
        n_streams,
        if prefill_chunk > 0 {
            format!(" prefill-chunk={prefill_chunk}")
        } else {
            String::new()
        },
        match &cfg.kv {
            Some(kv) => format!(" kv-cache={kv:?}"),
            None => " kv-cache=off".to_string(),
        }
    );

    let handle = attention_server::start(cfg.clone())?;
    let latency = obs::Histo::default();
    let t0 = std::time::Instant::now();
    for _ in 0..n_streams {
        let stream = handle.open_stream(stride);
        let token_elems = stream.token_elems();
        // same data seed per stream: replayed prompts exercise the cache
        let mut rng = Rng::new(11);
        if prefill_chunk > 0 {
            // prefill-throughput shape: chunked ingest, one final query
            let mut remaining = tokens;
            while remaining > 0 {
                let c = prefill_chunk.min(remaining);
                let mut mk = || {
                    let mut buf = vec![0.0f32; c * token_elems];
                    rng.fill_normal(&mut buf);
                    let slab: Arc<[f32]> = buf.into();
                    slab
                };
                let (k, v) = (mk(), mk());
                stream.prefill(k, v, c);
                remaining -= c;
            }
            let mut q = vec![0.0f32; token_elems];
            rng.fill_normal(&mut q);
            let step = std::time::Instant::now();
            let out = stream.query(q.into(), 1).recv().context("prefill query dropped")?;
            // drain latency: the query waits behind the whole ingest
            latency.record(step.elapsed().as_nanos() as u64);
            anyhow::ensure!(out.len() == token_elems);
            anyhow::ensure!(out.iter().all(|x| x.is_finite()));
        } else {
            for _ in 0..tokens {
                let mut mk = || {
                    let mut buf = vec![0.0f32; token_elems];
                    rng.fill_normal(&mut buf);
                    let slab: Arc<[f32]> = buf.into();
                    slab
                };
                let (k, v, q) = (mk(), mk(), mk());
                let step = std::time::Instant::now();
                stream.append(k, v);
                let out = stream.query(q, 1).recv().context("stream query dropped")?;
                latency.record(step.elapsed().as_nanos() as u64);
                anyhow::ensure!(out.len() == token_elems);
                anyhow::ensure!(out.iter().all(|x| x.is_finite()));
            }
        }
        stream.close();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown()?;
    let decoded = tokens * n_streams;
    println!(
        "{} {} tokens in {:.2}s ({:.1} tok/s) — appends={} queries={} rejected={}",
        if prefill_chunk > 0 { "prefilled" } else { "decoded" },
        decoded,
        wall,
        decoded as f64 / wall,
        stats.stream_appends,
        stats.stream_queries,
        stats.rejected
    );
    let snap = latency.snapshot();
    println!(
        "per-step ms: p50={:.2} p95={:.2} p99={:.2}",
        histo_ms(&snap, 50.0),
        histo_ms(&snap, 95.0),
        histo_ms(&snap, 99.0)
    );
    if cfg.kv.is_some() {
        println!(
            "kv cache: hit-blocks={} alloc-blocks={} evicted={} resident={} ({:.1} KiB KV)",
            stats.kv_hit_blocks,
            stats.kv_alloc_blocks,
            stats.kv_evicted_blocks,
            stats.kv_resident_blocks,
            stats.kv_resident_bytes as f64 / 1024.0
        );
        if cfg.kv.as_ref().is_some_and(|kv| kv.tiers.enabled()) {
            println!(
                "kv tiers: demoted={} spilled={} spill-hits={} spill-corrupt={}",
                stats.kv_demoted_blocks,
                stats.kv_spilled_blocks,
                stats.kv_spill_hits,
                stats.kv_spill_corrupt
            );
        }
    }
    Ok(())
}

fn cmd_serve_pjrt(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let n_requests = args.get_usize("requests", 64)?;
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5)?);
    eprintln!("starting inference server for {} ...", cfg.method);
    let task = data::by_name(&cfg.task, cfg.model.seq_len).context("task")?;
    let handle = coordinator::server::start(cfg, max_wait);

    let mut rng = Rng::new(7);
    let latency = obs::Histo::default();
    let sequences: Vec<Vec<i32>> =
        (0..n_requests).map(|_| task.sample(&mut rng).tokens).collect();
    let t0 = std::time::Instant::now();
    // batched submission: sequences land in the queue back-to-back so the
    // batcher fills whole batches instead of waiting out max_wait each
    let receivers = handle.submit_many(sequences);
    let submitted = std::time::Instant::now();
    for rx in receivers {
        let logits = match rx.recv() {
            Ok(l) => l,
            // reply channel closed: the serve thread bailed — surface its
            // own error (e.g. "PJRT unavailable" when the stub xla crate
            // is linked) instead of a bare channel error
            Err(_) => {
                return match handle.shutdown() {
                    Ok(stats) => {
                        Err(anyhow::anyhow!("server dropped requests (stats: {stats:?})"))
                    }
                    Err(e) => Err(e),
                };
            }
        };
        latency.record(submitted.elapsed().as_nanos() as u64);
        anyhow::ensure!(!logits.is_empty());
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown()?;
    println!(
        "served {} requests in {:.2}s ({:.1} req/s) — batches={} occupancy={:.2}",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        stats.batches,
        stats.mean_occupancy
    );
    let snap = latency.snapshot();
    println!(
        "latency ms: p50={:.1} p95={:.1} p99={:.1} (queue {:.1})",
        histo_ms(&snap, 50.0),
        histo_ms(&snap, 95.0),
        histo_ms(&snap, 99.0),
        stats.mean_queue_ms
    );
    Ok(())
}

/// `skein top --addr HOST:PORT`: live terminal view of a server or
/// coordinator, refreshed every `--interval-ms`.  Each refresh polls
/// the wire `Stats` reply: engine counters, span histogram percentiles
/// (milliseconds, log2-bucket upper bounds), and — against a
/// coordinator — per-shard health.  `--iterations N` stops after N
/// refreshes (0 = until killed); the last frame is left on screen.
fn cmd_top(args: &Args) -> Result<()> {
    use skeinformer::coordinator::net::NetClient;

    let addr = args
        .get("addr")
        .context("usage: skein top --addr HOST:PORT [--interval-ms N] [--iterations N]")?;
    let interval = Duration::from_millis(args.get_u64("interval-ms", 1000)?.max(50));
    let iterations = args.get_usize("iterations", 0)?;
    let mut client = NetClient::connect(addr).with_context(|| format!("connect {addr}"))?;
    let info = client.info().clone();
    let mut done = 0usize;
    loop {
        let sw = client.stats_full().context("stats poll")?;
        let s = &sw.stats;
        // ANSI clear + home: each refresh repaints from the top
        print!("\x1b[2J\x1b[H");
        println!(
            "skein top — {addr} method={} B<={} H={} n={} p={} (every {}ms)",
            info.method,
            info.max_batch,
            info.heads,
            info.seq,
            info.head_dim,
            interval.as_millis()
        );
        println!(
            "requests={} batches={} steps={} rejected={} appends={} queries={}",
            s.requests, s.batches, s.steps, s.rejected, s.stream_appends, s.stream_queries
        );
        println!(
            "occupancy={:.2} step-occupancy={:.2} queue={:.1}ms batch={:.1}ms",
            s.mean_occupancy, s.mean_step_occupancy, s.mean_queue_ms, s.mean_batch_ms
        );
        println!(
            "kv: hits={} allocs={} evicted={} resident={} ({:.1} KiB)",
            s.kv_hit_blocks,
            s.kv_alloc_blocks,
            s.kv_evicted_blocks,
            s.kv_resident_blocks,
            s.kv_resident_bytes as f64 / 1024.0
        );
        // one-hot ISA gauges; against a coordinator the gauges are
        // summed across shards, so values count engines per tier
        let isas: Vec<String> = sw
            .gauges
            .iter()
            .filter(|(name, v)| name.starts_with("skein_kernel_isa{") && *v > 0)
            .map(|(name, v)| {
                let tier = name
                    .trim_start_matches("skein_kernel_isa{isa=\"")
                    .trim_end_matches("\"}");
                format!("{tier}={v}")
            })
            .collect();
        if !isas.is_empty() {
            println!("kernel: {}", isas.join(" "));
        }
        let rows: Vec<Vec<String>> = sw
            .histos
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(name, h)| {
                vec![
                    name.clone(),
                    h.count().to_string(),
                    format!("{:.3}", h.mean_ns() / 1e6),
                    format!("{:.3}", histo_ms(h, 50.0)),
                    format!("{:.3}", histo_ms(h, 95.0)),
                    format!("{:.3}", histo_ms(h, 99.0)),
                ]
            })
            .collect();
        if !rows.is_empty() {
            println!(
                "{}",
                bench_util::ascii_table(
                    &["span", "count", "mean ms", "p50 ms", "p95 ms", "p99 ms"],
                    &rows
                )
            );
        }
        if !sw.shards.is_empty() {
            let rows: Vec<Vec<String>> = sw
                .shards
                .iter()
                .map(|h| {
                    vec![
                        h.addr.clone(),
                        if h.alive { "live".into() } else { "dead".to_string() },
                        h.heartbeat_age_ms.to_string(),
                        h.pending.to_string(),
                        h.queue_depth.to_string(),
                        h.down_drains.to_string(),
                    ]
                })
                .collect();
            println!(
                "{}",
                bench_util::ascii_table(
                    &["shard", "state", "hb age ms", "pending", "queue", "down drains"],
                    &rows
                )
            );
        }
        done += 1;
        if iterations > 0 && done >= iterations {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// `skein scrape --addr HOST:PORT`: fetch `/metrics` over one raw HTTP
/// GET and validate the Prometheus text exposition — at least one
/// `# TYPE` line, at least one sample, and every non-comment line a
/// `name value` pair with a numeric value.  Exits nonzero on anything
/// malformed, so CI smoke tests can assert scrapeability.
fn cmd_scrape(args: &Args) -> Result<()> {
    use std::io::{Read, Write};

    let addr = args.get("addr").context("usage: skein scrape --addr HOST:PORT")?;
    let mut sock =
        std::net::TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    sock.set_read_timeout(Some(Duration::from_secs(5)))?;
    sock.set_write_timeout(Some(Duration::from_secs(5)))?;
    write!(sock, "GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    sock.read_to_string(&mut raw).context("read response")?;
    let (head, body) =
        raw.split_once("\r\n\r\n").context("no header/body split in HTTP response")?;
    let status = head.lines().next().unwrap_or("");
    anyhow::ensure!(
        status.starts_with("HTTP/1.1 200"),
        "expected HTTP/1.1 200 from {addr}, got {status:?}"
    );
    let (mut types, mut samples) = (0usize, 0usize);
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            if comment.trim_start().starts_with("TYPE ") {
                types += 1;
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let (name, value) = (it.next(), it.next());
        anyhow::ensure!(
            name.is_some() && value.is_some() && it.next().is_none(),
            "malformed sample line {line:?}: expected `name value`"
        );
        anyhow::ensure!(
            value.unwrap().parse::<f64>().is_ok(),
            "non-numeric value in sample line {line:?}"
        );
        samples += 1;
    }
    anyhow::ensure!(types > 0, "no # TYPE lines in exposition from {addr}");
    anyhow::ensure!(samples > 0, "no sample lines in exposition from {addr}");
    println!("scraped {addr}: {samples} sample(s), {types} # TYPE line(s) — well-formed");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: skein inspect <manifest.json>")?;
    let text = std::fs::read_to_string(path)?;
    let j = json::parse(&text)?;
    let dir = std::path::Path::new(path).parent().unwrap_or(std::path::Path::new("."));
    let man = skeinformer::runtime::ArtifactManifest::from_json(&j, dir)?;
    println!("method: {}", man.method);
    println!("config: {:?}", man.config);
    println!("params: {} tensors, {} f32 total", man.params.len(), man.params_f32_count);
    for p in &man.params {
        println!("  {:<24} {:?}", p.name, p.shape);
    }
    println!("train inputs: {}", man.train_inputs.len());
    println!("train hlo: {:?}", man.train_path());
    println!("forward hlo: {:?}", man.forward_path());
    Ok(())
}
