//! Mini property-testing substrate (no proptest offline).
//!
//! A [`Runner`] drives a property over many generated cases; on failure it
//! performs greedy shrinking over the recorded scalar choices and reports
//! the minimal failing case's seed so the exact case replays:
//!
//! ```
//! use skeinformer::prop::{Runner, Gen};
//! Runner::new("addition commutes", 200).run(|g| {
//!     let a = g.int(0, 1000) as i64;
//!     let b = g.int(0, 1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Rng;

/// A source of generated values for one test case.
pub struct Gen {
    rng: Rng,
    /// Shrink pass: when set, integer choices are biased toward minimum.
    shrink_level: u32,
}

impl Gen {
    fn new(seed: u64, shrink_level: u32) -> Self {
        Self { rng: Rng::new(seed), shrink_level }
    }

    /// Integer in `[lo, hi]` inclusive; shrink passes bias toward `lo`.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = hi - lo + 1;
        let mut x = self.rng.below(span);
        for _ in 0..self.shrink_level {
            x /= 2;
        }
        lo + x
    }

    /// Power-of-two integer in `[lo, hi]` (both must be powers of two).
    pub fn pow2(&mut self, lo: usize, hi: usize) -> usize {
        let lo_bits = lo.trailing_zeros();
        let hi_bits = hi.trailing_zeros();
        1usize << self.int(lo_bits as usize, hi_bits as usize)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Pick one of the given items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// A vector of f32 with the given length and element range.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    /// Raw access to the underlying RNG for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Property runner.
pub struct Runner {
    name: &'static str,
    cases: u64,
    base_seed: u64,
}

impl Runner {
    pub fn new(name: &'static str, cases: u64) -> Self {
        // different properties get decorrelated default seeds
        let base_seed = name.bytes().fold(0xA5A5_1234u64, |a, b| {
            a.wrapping_mul(31).wrapping_add(b as u64)
        });
        Self { name, cases, base_seed }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Run the property; panics (with seed info) on the first failure after
    /// attempting shrink passes.
    pub fn run(&self, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
            let outcome = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed, 0);
                prop(&mut g);
            });
            if outcome.is_err() {
                // greedy shrink: re-run with increasing shrink bias, keep
                // the deepest level that still fails.
                let mut min_level = 0;
                for level in 1..=8u32 {
                    let fails = std::panic::catch_unwind(|| {
                        let mut g = Gen::new(seed, level);
                        prop(&mut g);
                    })
                    .is_err();
                    if fails {
                        min_level = level;
                    }
                }
                // reproduce the minimal case loudly
                let mut g = Gen::new(seed, min_level);
                eprintln!(
                    "property {:?} failed: case {case}, seed {seed:#x}, shrink level {min_level}",
                    self.name
                );
                prop(&mut g); // panics again with the original assertion
                unreachable!("shrunk case stopped failing — flaky property?");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Runner::new("sum-nonneg", 100).run(|g| {
            let a = g.int(0, 50);
            let b = g.int(0, 50);
            assert!(a + b <= 100);
        });
    }

    #[test]
    fn failing_property_is_detected() {
        let result = std::panic::catch_unwind(|| {
            Runner::new("always-fails-above-10", 200).run(|g| {
                let x = g.int(0, 100);
                assert!(x <= 10, "x = {x}");
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn generators_respect_ranges() {
        Runner::new("ranges", 300).run(|g| {
            let i = g.int(3, 9);
            assert!((3..=9).contains(&i));
            let p = g.pow2(4, 64);
            assert!(p.is_power_of_two() && (4..=64).contains(&p));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_f32(5, 0.0, 2.0);
            assert_eq!(v.len(), 5);
        });
    }

    #[test]
    fn choose_picks_members() {
        Runner::new("choose", 100).run(|g| {
            let items = ["a", "b", "c"];
            let x = g.choose(&items);
            assert!(items.contains(x));
        });
    }
}
