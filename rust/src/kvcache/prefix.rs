//! The prefix-sharing index: a radix trie over sealed block content
//! hashes.
//!
//! A stream's sealed blocks form a path of content hashes `h₀ h₁ h₂ …`
//! from the trie root; the node at depth `i` holds the shared
//! `Arc<KvBlock>` for the stream's `i`-th block.  Two streams whose
//! prompts share a prefix walk the same hash path and receive the same
//! physical blocks — [`PrefixIndex::lookup`] verifies every hash hit by
//! full content comparison ([`KvBlock::content_eq`]), so a hash collision
//! degrades to a miss, never to shared wrong bytes.
//!
//! **Invariants.**
//!
//! * A node's position encodes its *absolute* prefix path — blocks are
//!   only ever shared between streams whose entire preceding token
//!   sequences were bitwise identical.
//! * Eviction ([`PrefixIndex::evict_lru`]) only ever removes a block with
//!   no holder outside the index (`Arc` strong count 1): a block a live
//!   stream still references is never dropped.
//! * An evicted interior node leaves a block-less *tombstone* so its
//!   descendants stay addressable (a sliding-window stream may drop its
//!   front blocks — unpinning them — while it keeps sealing deeper ones
//!   on the same path); evicted leaves are removed and empty tombstone
//!   chains pruned.
//! * Every insert and every hit stamps a unique logical-clock value, so
//!   LRU selection has no ties and is deterministic regardless of hash-map
//!   iteration order.

use super::block::KvBlock;
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Debug)]
struct TrieNode {
    /// The shared block, or `None` for a tombstone (evicted interior
    /// node kept only to keep descendants addressable).
    block: Option<Arc<KvBlock>>,
    children: HashMap<u64, TrieNode>,
    /// Logical-clock stamp of the last insert/hit (unique per node).
    last_touch: u64,
}

/// Radix trie mapping sealed-block hash paths to shared blocks.  See the
/// [module docs](self) for the invariants.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    children: HashMap<u64, TrieNode>,
    clock: u64,
    /// Nodes currently holding a block (tombstones excluded).
    entries: usize,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Blocks currently held by the index.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    fn node(&self, path: &[u64]) -> Option<&TrieNode> {
        let (&first, rest) = path.split_first()?;
        let mut node = self.children.get(&first)?;
        for h in rest {
            node = node.children.get(h)?;
        }
        Some(node)
    }

    fn node_mut(&mut self, path: &[u64]) -> Option<&mut TrieNode> {
        let (&first, rest) = path.split_first()?;
        let mut node = self.children.get_mut(&first)?;
        for h in rest {
            node = node.children.get_mut(h)?;
        }
        Some(node)
    }

    /// Look up a just-sealed block: does a stream whose previous sealed
    /// blocks hashed to `path` already have a shared block with
    /// `candidate`'s contents?  On a verified hit the node is touched
    /// (LRU) and its `Arc` cloned out; hash matches with different
    /// contents are misses.
    pub fn lookup(&mut self, path: &[u64], hash: u64, candidate: &KvBlock) -> Option<Arc<KvBlock>> {
        self.clock += 1;
        let stamp = self.clock;
        let children = match path.is_empty() {
            true => &mut self.children,
            false => &mut self.node_mut(path)?.children,
        };
        let node = children.get_mut(&hash)?;
        let block = node.block.as_ref()?;
        if !block.content_eq(candidate) {
            return None; // hash collision: treat as a miss, never share
        }
        node.last_touch = stamp;
        Some(Arc::clone(node.block.as_ref().expect("checked above")))
    }

    /// Register a freshly sealed block at `path` + `hash`.  Missing
    /// intermediate nodes (evicted ancestors of a sliding-window stream)
    /// are recreated as tombstones; an existing tombstone at the target
    /// is re-armed with the block.  The displaced block, if any (a hash
    /// collision overwriting a different-content entry), is returned so
    /// the caller can release it back to the pool — the index never
    /// drops an `Arc` the pool's residency ledger is tracking.
    pub fn insert(&mut self, path: &[u64], hash: u64, block: Arc<KvBlock>) -> Option<Arc<KvBlock>> {
        self.clock += 1;
        let stamp = self.clock;
        let mut children = &mut self.children;
        for h in path {
            children = &mut children
                .entry(*h)
                .or_insert_with(|| TrieNode {
                    block: None,
                    children: HashMap::new(),
                    last_touch: 0,
                })
                .children;
        }
        let node = children.entry(hash).or_insert_with(|| TrieNode {
            block: None,
            children: HashMap::new(),
            last_touch: 0,
        });
        let displaced = node.block.take();
        if displaced.is_none() {
            self.entries += 1;
        }
        node.block = Some(block);
        node.last_touch = stamp;
        displaced
    }

    /// Remove the entry at `path` + `hash` if its block is exactly the
    /// one `holder` shares and nothing else references it (`Arc` strong
    /// count ≤ 2: the index plus `holder`).  Used by the sliding-window
    /// path when no capacity bound exists to reclaim retention later.
    /// Returns the removed `Arc` for the caller to release.
    pub fn remove_if_unshared(
        &mut self,
        path: &[u64],
        hash: u64,
        holder: &Arc<KvBlock>,
    ) -> Option<Arc<KvBlock>> {
        let children = match path.is_empty() {
            true => &mut self.children,
            false => &mut self.node_mut(path)?.children,
        };
        let node = children.get_mut(&hash)?;
        let block = node.block.as_ref()?;
        if !Arc::ptr_eq(block, holder) || Arc::strong_count(block) > 2 {
            return None; // another stream still shares it: keep
        }
        let removed = node.block.take().expect("checked above");
        self.entries -= 1;
        let mut full_path = path.to_vec();
        full_path.push(hash);
        prune(&mut self.children, &full_path);
        Some(removed)
    }

    /// Evict the least-recently-touched block that nothing outside the
    /// index references (`Arc` strong count 1), or `None` when every
    /// held block is still referenced elsewhere.
    pub fn evict_lru(&mut self) -> Option<Arc<KvBlock>> {
        self.evict_lru_batch(1).pop()
    }

    /// Evict up to `max` least-recently-touched unreferenced blocks in
    /// **one** trie pass (the capacity catch-up path would otherwise pay
    /// a full DFS per block).  Interior nodes tombstone (descendants
    /// stay addressable); leaves are removed and empty tombstone chains
    /// pruned.  Returns the evicted `Arc`s for the caller to release
    /// back to the pool, oldest first — possibly fewer than `max`.
    pub fn evict_lru_batch(&mut self, max: usize) -> Vec<Arc<KvBlock>> {
        if max == 0 {
            return Vec::new();
        }
        let mut candidates = Vec::new();
        let mut path = Vec::new();
        find_evictable(&self.children, &mut path, &mut candidates);
        // unique stamps make the order (and the evicted set) deterministic
        candidates.sort_unstable_by_key(|(stamp, _)| *stamp);
        candidates.truncate(max);
        let mut evicted = Vec::with_capacity(candidates.len());
        for (_, path) in candidates {
            let node = self.node_mut(&path).expect("evictable path just found");
            let block = node.block.take().expect("evictable node holds a block");
            self.entries -= 1;
            prune(&mut self.children, &path);
            evicted.push(block);
        }
        evicted
    }
}

/// DFS collecting `(last_touch, path)` of every evictable node (block
/// held, strong count 1).
fn find_evictable(
    children: &HashMap<u64, TrieNode>,
    path: &mut Vec<u64>,
    out: &mut Vec<(u64, Vec<u64>)>,
) {
    for (&h, node) in children {
        path.push(h);
        if let Some(block) = &node.block {
            if Arc::strong_count(block) == 1 {
                out.push((node.last_touch, path.clone()));
            }
        }
        find_evictable(&node.children, path, out);
        path.pop();
    }
}

/// Remove the node at `path` if it is an empty tombstone, cascading up
/// through ancestors that become empty tombstones themselves.
fn prune(children: &mut HashMap<u64, TrieNode>, path: &[u64]) {
    let Some((&first, rest)) = path.split_first() else {
        return;
    };
    if let Some(node) = children.get_mut(&first) {
        prune(&mut node.children, rest);
        if node.block.is_none() && node.children.is_empty() {
            children.remove(&first);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sealed(token_elems: usize, fill: f32) -> Arc<KvBlock> {
        let mut b = KvBlock::from_storage(vec![0.0; token_elems], vec![0.0; token_elems], token_elems);
        b.push(&vec![fill; token_elems], &vec![fill * 2.0; token_elems]);
        Arc::new(b)
    }

    #[test]
    fn lookup_hits_only_verified_content_at_the_same_path() {
        let mut idx = PrefixIndex::new();
        let b0 = sealed(2, 1.0);
        let h0 = b0.content_hash();
        assert!(idx.insert(&[], h0, Arc::clone(&b0)).is_none());
        assert_eq!(idx.len(), 1);
        // same path, same content: hit
        let probe = sealed(2, 1.0);
        let hit = idx.lookup(&[], probe.content_hash(), &probe).expect("hit");
        assert!(Arc::ptr_eq(&hit, &b0));
        // different path (depth 1): miss even with equal content
        assert!(idx.lookup(&[h0], probe.content_hash(), &probe).is_none());
        // unknown hash: miss
        assert!(idx.lookup(&[], h0 ^ 1, &probe).is_none());
    }

    #[test]
    fn eviction_skips_referenced_blocks() {
        let mut idx = PrefixIndex::new();
        let held = sealed(2, 1.0);
        let loose = sealed(2, 2.0);
        let _ = idx.insert(&[], held.content_hash(), Arc::clone(&held)); // 2 refs
        let _ = idx.insert(&[], loose.content_hash(), loose); // 1 ref (index only)
        let evicted = idx.evict_lru().expect("loose block evictable");
        assert_eq!(evicted.k_token(0)[0], 2.0, "must evict the unreferenced block");
        assert_eq!(idx.len(), 1);
        assert!(idx.evict_lru().is_none(), "held block must never be evicted");
        drop(held);
        assert!(idx.evict_lru().is_some(), "released block becomes evictable");
        assert!(idx.is_empty());
    }

    #[test]
    fn lru_order_follows_touches() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let _ = idx.insert(&[], a.content_hash(), Arc::clone(&a));
        let _ = idx.insert(&[], b.content_hash(), Arc::clone(&b));
        // touch a, making b the LRU
        let probe = sealed(2, 1.0);
        idx.lookup(&[], probe.content_hash(), &probe).expect("hit a");
        drop(a);
        drop(b);
        let evicted = idx.evict_lru().expect("evictable");
        assert_eq!(evicted.k_token(0)[0], 2.0, "least-recently-touched first");
    }

    #[test]
    fn interior_eviction_tombstones_and_reinsert_rearms() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, Arc::clone(&parent));
        let _ = idx.insert(&[hp], hc, Arc::clone(&child));
        drop(parent); // only the index holds the parent now
        let evicted = idx.evict_lru().expect("parent evictable");
        assert_eq!(evicted.k_token(0)[0], 1.0);
        assert_eq!(idx.len(), 1);
        // the child stays addressable through the tombstone
        let probe = sealed(2, 2.0);
        let hit = idx.lookup(&[hp], probe.content_hash(), &probe).expect("child survives");
        assert!(Arc::ptr_eq(&hit, &child));
        // re-arming the tombstone counts as one entry again
        let parent2 = sealed(2, 1.0);
        assert!(idx.insert(&[], hp, parent2).is_none(), "tombstone re-arm displaces nothing");
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn insert_returns_the_displaced_block() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let h = a.content_hash();
        assert!(idx.insert(&[], h, Arc::clone(&a)).is_none());
        // simulated hash collision: different content forced onto the
        // same key must hand the old block back, not drop it
        let displaced = idx.insert(&[], h, Arc::clone(&b)).expect("displaced block returned");
        assert!(Arc::ptr_eq(&displaced, &a));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn remove_if_unshared_respects_other_holders() {
        let mut idx = PrefixIndex::new();
        let block = sealed(2, 1.0);
        let h = block.content_hash();
        let _ = idx.insert(&[], h, Arc::clone(&block)); // index + `block` = 2 refs
        let outside = Arc::clone(&block); // a third holder (another stream)
        assert!(idx.remove_if_unshared(&[], h, &block).is_none(), "shared: must keep");
        drop(outside);
        let removed = idx.remove_if_unshared(&[], h, &block).expect("unshared: removed");
        assert!(Arc::ptr_eq(&removed, &block));
        assert!(idx.is_empty());
    }

    #[test]
    fn batch_eviction_takes_oldest_first_in_one_pass() {
        let mut idx = PrefixIndex::new();
        let blocks: Vec<_> = (0..4).map(|i| sealed(2, i as f32 + 1.0)).collect();
        for b in &blocks {
            let _ = idx.insert(&[], b.content_hash(), Arc::clone(b));
        }
        let keep = Arc::clone(&blocks[0]); // oldest stamp, but referenced
        drop(blocks);
        let evicted = idx.evict_lru_batch(2);
        assert_eq!(evicted.len(), 2);
        assert_eq!(evicted[0].k_token(0)[0], 2.0, "oldest unreferenced first");
        assert_eq!(evicted[1].k_token(0)[0], 3.0);
        assert_eq!(idx.len(), 2);
        drop(keep);
        assert_eq!(idx.evict_lru_batch(10).len(), 2, "remainder evictable once released");
    }

    #[test]
    fn leaf_eviction_prunes_empty_tombstone_chains() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, parent);
        let _ = idx.insert(&[hp], hc, child);
        // evict both (insertion order: parent is older)
        assert!(idx.evict_lru().is_some());
        assert!(idx.evict_lru().is_some());
        assert!(idx.is_empty());
        assert!(idx.children.is_empty(), "tombstone chain must be pruned");
    }
}
