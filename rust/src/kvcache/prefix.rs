//! The prefix-sharing index: a radix trie over sealed block content
//! hashes.
//!
//! A stream's sealed blocks form a path of content hashes `h₀ h₁ h₂ …`
//! from the trie root; the node at depth `i` holds the shared
//! [`CacheEntry`] for the stream's `i`-th block — a hot `Arc<KvBlock>`,
//! a quantised [`QuantBlock`](super::QuantBlock), or a disk-only
//! `Spilled` marker (see the [`TierLadder`](super::TierLadder)).
//! Two streams whose prompts share a prefix walk the same hash path and
//! receive the same physical blocks — every hash hit is verified against
//! the freshly sealed candidate before sharing (bitwise
//! [`KvBlock::content_eq`] for hot entries; the cache layer re-encodes or
//! re-reads for quantised/spilled ones), so a hash collision degrades to
//! a miss, never to shared wrong bytes.
//!
//! **Invariants.**
//!
//! * A node's position encodes its *absolute* prefix path — blocks are
//!   only ever shared between streams whose entire preceding token
//!   sequences were bitwise identical.
//! * Eviction and demotion only ever touch an entry with no holder
//!   outside the index ([`CacheEntry::ram_unreferenced`]): a block a
//!   live stream still references is never dropped or quantised under
//!   it.  That is also what keeps chain gathers free of disk reads — a
//!   chain-held block can never become `Spilled`.
//! * An evicted interior node leaves an entry-less *tombstone* so its
//!   descendants stay addressable (a sliding-window stream may drop its
//!   front blocks — unpinning them — while it keeps sealing deeper ones
//!   on the same path); evicted leaves are removed and empty tombstone
//!   chains pruned.
//! * Every insert and every hit stamps a unique logical-clock value, so
//!   LRU selection has no ties and is deterministic regardless of hash-map
//!   iteration order.  The hit path is split into [`PrefixIndex::probe`]
//!   (one clock bump, hit or miss — exactly what the old fused lookup
//!   did) and [`PrefixIndex::touch_probed`] (stamp on a confirmed hit),
//!   so the cache layer can interpose tier-specific verification without
//!   perturbing the stamp sequence tiers-off serving produces.
//! * **LRU selection is O(log N), not a trie walk.**  Every stamp
//!   assignment also pushes a `(stamp, node id)` snapshot onto a
//!   min-heap; the node's `last_touch` stays the single source of truth,
//!   and a popped snapshot whose stamp no longer matches (the node was
//!   re-touched, evicted, or removed) is simply discarded — *lazy
//!   invalidation*.  A popped entry whose payload a live stream still
//!   references is pushed back and retried on a later eviction pass; a
//!   popped `Spilled` entry's snapshot is discarded outright (nothing
//!   resident remains to reclaim, and a later promotion re-stamps it).
//!   Because stamps are unique, the heap's pop order is a total order,
//!   and the evicted sequence is exactly what a full-trie DFS sorted by
//!   stamp would produce (pinned against the `#[cfg(test)]` DFS oracle
//!   under randomized interleavings).
//! * **Demotion rides the same heap.**  [`PrefixIndex::demote_lru_batch`]
//!   pops snapshots in stamp order like eviction, but instead of
//!   dropping a victim it hands the owned entry to a caller closure that
//!   returns the next-rung replacement (or `None` to drop).  A re-armed
//!   node keeps its stamp — its LRU position is unchanged, so it keeps
//!   sinking one rung per pressure pass — and its snapshot is deferred
//!   until the pass ends, so one pass never sinks the same block twice.
//! * **Nodes live in an arena of stable ids.**  Trie edges are
//!   `hash → NodeId` and each LRU snapshot is a two-word
//!   `(stamp, NodeId)` — O(1) per snapshot, instead of the retired
//!   owned-path snapshots whose memory was O(Σ depth), quadratic for one
//!   deep chain.  Pruned nodes return their ids to a free list for
//!   reuse; a stale snapshot aimed at a reused id is inert because the
//!   new tenant carries a strictly newer stamp (or no entry yet), so the
//!   stamp check rejects it.

use super::block::KvBlock;
use super::tier::{CacheEntry, SealedRef};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

/// Stable arena index of one trie node.  Valid only until the next
/// operation that can prune or reuse nodes (eviction, demotion,
/// removal) — the cache layer only holds one across a probe → touch /
/// replace sequence, which does neither.
pub type NodeId = usize;

/// The arena slot of the (entry-less, unprunable) root node.
const ROOT: NodeId = 0;

/// One lazy LRU snapshot: the stamp a node carried when it was touched,
/// plus the node's stable arena id — two words, O(1) regardless of trie
/// depth.
type LruEntry = Reverse<(u64, NodeId)>;

#[derive(Debug)]
struct TrieNode {
    /// The shared cache entry, or `None` for a tombstone (evicted
    /// interior node kept only to keep descendants addressable) and for
    /// the root.
    entry: Option<CacheEntry>,
    children: HashMap<u64, NodeId>,
    /// Logical-clock stamp of the last insert/hit (unique per node).
    last_touch: u64,
    /// Arena id of the parent (`ROOT` points at itself) — what lets
    /// pruning cascade upward without re-walking a path.
    parent: NodeId,
    /// The hash this node hangs under in its parent's `children`.
    key: u64,
}

/// Radix trie mapping sealed-block hash paths to shared cache entries.
/// See the [module docs](self) for the invariants.
#[derive(Debug)]
pub struct PrefixIndex {
    /// Node arena; slot 0 is the root, `None` slots are on `free`.
    arena: Vec<Option<TrieNode>>,
    /// Freed arena slots awaiting reuse.
    free: Vec<NodeId>,
    clock: u64,
    /// Nodes currently holding an entry (tombstones excluded; spilled
    /// entries included — they are addressable cache state).
    entries: usize,
    /// Min-heap of `(last_touch, node id)` snapshots — the O(log N) LRU.
    /// May hold stale entries (lazy invalidation; see the module docs);
    /// compacted by an arena scan when stale entries dominate.
    lru: BinaryHeap<LruEntry>,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefixIndex {
    pub fn new() -> Self {
        let root = TrieNode {
            entry: None,
            children: HashMap::new(),
            last_touch: 0,
            parent: ROOT,
            key: 0,
        };
        Self {
            arena: vec![Some(root)],
            free: Vec::new(),
            clock: 0,
            entries: 0,
            lru: BinaryHeap::new(),
        }
    }

    /// Entries currently held by the index (all tiers, spilled included).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    fn node(&self, id: NodeId) -> &TrieNode {
        self.arena[id].as_ref().expect("live node id")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut TrieNode {
        self.arena[id].as_mut().expect("live node id")
    }

    /// Follow `path` from the root; `None` if any edge is missing.
    fn walk(&self, path: &[u64]) -> Option<NodeId> {
        let mut at = ROOT;
        for h in path {
            at = *self.node(at).children.get(h)?;
        }
        Some(at)
    }

    /// Reconstruct a node's full hash path (ancestor hashes + its own
    /// key, root-first) by walking parent links — O(depth), used on the
    /// cold demotion/spill paths where the chain's path is not at hand.
    fn path_of(&self, id: NodeId) -> Vec<u64> {
        let mut path = Vec::new();
        let mut at = id;
        while at != ROOT {
            let node = self.node(at);
            path.push(node.key);
            at = node.parent;
        }
        path.reverse();
        path
    }

    /// Allocate a fresh tombstone node under `parent`, reusing a freed
    /// arena slot when one exists.
    fn alloc_child(&mut self, parent: NodeId, key: u64) -> NodeId {
        let node = TrieNode {
            entry: None,
            children: HashMap::new(),
            last_touch: 0,
            parent,
            key,
        };
        let id = match self.free.pop() {
            Some(id) => {
                self.arena[id] = Some(node);
                id
            }
            None => {
                self.arena.push(Some(node));
                self.arena.len() - 1
            }
        };
        self.node_mut(parent).children.insert(key, id);
        id
    }

    /// First half of a seal-time lookup: advance the clock (hit or miss,
    /// exactly like the old fused lookup) and resolve `path` + `hash` to
    /// its live node.  The caller inspects the node's entry
    /// ([`entry_cloned`](Self::entry_cloned)), runs its tier-specific
    /// verification, and either confirms the hit with
    /// [`touch_probed`](Self::touch_probed) / swaps the representation
    /// with [`replace_entry`](Self::replace_entry), or treats it as a
    /// miss and falls back to [`insert`](Self::insert).  The returned id
    /// stays valid across that sequence because none of it can prune or
    /// reuse nodes.
    pub fn probe(&mut self, path: &[u64], hash: u64) -> Option<NodeId> {
        self.clock += 1;
        let at = self.walk(path)?;
        self.node(at).children.get(&hash).copied()
    }

    /// The probed node's entry, cloned out (Arc clones — cheap) so the
    /// caller can verify it without holding a borrow on the index.
    pub fn entry_cloned(&self, id: NodeId) -> Option<CacheEntry> {
        self.arena.get(id)?.as_ref()?.entry.clone()
    }

    /// Stamp a just-probed node with the probe's clock value — the
    /// LRU-touch half of a confirmed hit.
    pub fn touch_probed(&mut self, id: NodeId) {
        let stamp = self.clock;
        self.node_mut(id).last_touch = stamp;
        self.push_lru(stamp, id);
    }

    /// Swap a just-probed node's entry for a different representation of
    /// the *same content* (spilled→hot promotion on a verified rehydrate;
    /// corrupt-spill refresh).  No clock or stamp change — pair with
    /// [`touch_probed`](Self::touch_probed) when the swap is a hit.
    /// Returns the previous entry (the node must hold one: promotion
    /// never creates or destroys entries, so `entries` stays exact).
    pub fn replace_entry(&mut self, id: NodeId, entry: CacheEntry) -> Option<CacheEntry> {
        let node = self.node_mut(id);
        debug_assert!(node.entry.is_some(), "replace_entry on a tombstone");
        node.entry.replace(entry)
    }

    /// Look up a just-sealed block: does a stream whose previous sealed
    /// blocks hashed to `path` already have a shared *hot* block with
    /// `candidate`'s contents?  On a verified hit the node is touched
    /// (LRU) and its `Arc` cloned out; hash matches with different
    /// contents — and entries at colder tiers, which need the cache
    /// layer's codec/store verification — are misses.  (The cache layer
    /// uses the [`probe`](Self::probe) flow directly; this fused form
    /// serves the hot-only callers and the tests.)
    pub fn lookup(&mut self, path: &[u64], hash: u64, candidate: &KvBlock) -> Option<Arc<KvBlock>> {
        let id = self.probe(path, hash)?;
        let node = self.node_mut(id);
        let Some(CacheEntry::Hot(block)) = node.entry.as_ref() else {
            return None;
        };
        if !block.content_eq(candidate) {
            return None; // hash collision: treat as a miss, never share
        }
        let shared = Arc::clone(block);
        self.touch_probed(id);
        Some(shared)
    }

    /// Register a freshly sealed entry at `path` + `hash`.  Missing
    /// intermediate nodes (evicted ancestors of a sliding-window stream)
    /// are recreated as tombstones; an existing tombstone at the target
    /// is re-armed.  The displaced entry, if any (a hash collision
    /// overwriting different content, or a corrupt spilled entry being
    /// replaced), is returned so the caller can release its payload —
    /// the index never drops an `Arc` the pool's residency ledger is
    /// tracking.
    pub fn insert(&mut self, path: &[u64], hash: u64, entry: CacheEntry) -> Option<CacheEntry> {
        self.clock += 1;
        let stamp = self.clock;
        let mut at = ROOT;
        for &h in path {
            at = match self.node(at).children.get(&h) {
                Some(&id) => id,
                None => self.alloc_child(at, h),
            };
        }
        let target = match self.node(at).children.get(&hash) {
            Some(&id) => id,
            None => self.alloc_child(at, hash),
        };
        let node = self.node_mut(target);
        let displaced = node.entry.take();
        node.entry = Some(entry);
        node.last_touch = stamp;
        if displaced.is_none() {
            self.entries += 1;
        }
        self.push_lru(stamp, target);
        displaced
    }

    /// Record a fresh `(stamp, node id)` LRU snapshot, compacting the
    /// heap when stale snapshots dominate the live entry count (a long
    /// run of hits with no eviction would otherwise grow it without
    /// bound).
    fn push_lru(&mut self, stamp: u64, id: NodeId) {
        self.lru.push(Reverse((stamp, id)));
        if self.lru.len() > 64 && self.lru.len() > 4 * self.entries.max(1) {
            // rebuild from the arena's current stamps: one snapshot per
            // entry-holding node.  Heap pops depend only on the (unique)
            // stamps, so a rebuild never changes the eviction order.
            let mut rebuilt = BinaryHeap::with_capacity(self.entries);
            for (id, slot) in self.arena.iter().enumerate() {
                if let Some(node) = slot {
                    if node.entry.is_some() {
                        rebuilt.push(Reverse((node.last_touch, id)));
                    }
                }
            }
            self.lru = rebuilt;
        }
    }

    /// Remove the entry at `path` + `hash` if its payload is exactly the
    /// one `holder` shares and nothing else references it (`Arc` strong
    /// count ≤ 2: the index plus `holder`).  Used by the sliding-window
    /// path when no capacity bound exists to reclaim retention later,
    /// and by batch-chain release at request completion.  An entry at a
    /// different tier than the holder (the chain kept a hot ref while
    /// the index entry was displaced and re-inserted) never matches.
    /// Returns the removed entry for the caller to release.
    pub fn remove_if_unshared(
        &mut self,
        path: &[u64],
        hash: u64,
        holder: &SealedRef,
    ) -> Option<CacheEntry> {
        let at = self.walk(path)?;
        let id = *self.node(at).children.get(&hash)?;
        let node = self.node_mut(id);
        let unshared = match (node.entry.as_ref()?, holder) {
            (CacheEntry::Hot(b), SealedRef::Hot(h)) => {
                Arc::ptr_eq(b, h) && Arc::strong_count(b) <= 2
            }
            (CacheEntry::Quant(q), SealedRef::Quant(h)) => {
                Arc::ptr_eq(q, h) && Arc::strong_count(q) <= 2
            }
            _ => false,
        };
        if !unshared {
            return None; // another stream still shares it (or tier mismatch): keep
        }
        let removed = node.entry.take().expect("checked above");
        self.entries -= 1;
        self.prune_up(id);
        Some(removed)
    }

    /// Evict the least-recently-touched RAM entry that nothing outside
    /// the index references, or `None` when every held payload is still
    /// referenced elsewhere.
    pub fn evict_lru(&mut self) -> Option<CacheEntry> {
        self.evict_lru_batch(1).pop()
    }

    /// Evict up to `max` least-recently-touched unreferenced RAM entries
    /// — O(log N) heap pops per victim instead of a full trie DFS per
    /// sealed block (the steady-state capacity-pressure cost this
    /// replaces).  Snapshots are popped in global stamp order: stale ones
    /// (node gone, tombstoned, re-touched under a newer stamp, or a
    /// freed id's new tenant) are discarded, snapshots of payloads a
    /// live stream still references are set aside and pushed back for a
    /// later pass, and `Spilled` snapshots are discarded outright
    /// (nothing resident to reclaim).  Interior nodes tombstone
    /// (descendants stay addressable); leaves are removed and empty
    /// tombstone chains pruned.  Returns the evicted entries for the
    /// caller to release back to the pool, oldest first — possibly fewer
    /// than `max`.  The order matches the `#[cfg(test)]` DFS oracle
    /// exactly (unique stamps leave no ties).
    pub fn evict_lru_batch(&mut self, max: usize) -> Vec<CacheEntry> {
        let mut evicted = Vec::new();
        let mut still_referenced: Vec<LruEntry> = Vec::new();
        while evicted.len() < max {
            let Some(Reverse((stamp, id))) = self.lru.pop() else {
                break; // heap drained: nothing held is evictable
            };
            let Some(node) = self.arena[id].as_mut() else {
                continue; // stale: the node was evicted and pruned
            };
            let Some(entry) = node.entry.as_ref() else {
                continue; // stale: tombstoned or removed since the snapshot
            };
            if node.last_touch != stamp {
                continue; // stale: re-touched — a newer snapshot exists
            }
            if matches!(entry, CacheEntry::Spilled) {
                continue; // disk-only: no RAM to reclaim — drop the snapshot
            }
            if !entry.ram_unreferenced() {
                // live-referenced: not evictable *now*, but this snapshot
                // is the node's current one — keep it for later passes
                still_referenced.push(Reverse((stamp, id)));
                continue;
            }
            let entry = node.entry.take().expect("checked above");
            self.entries -= 1;
            self.prune_up(id);
            evicted.push(entry);
        }
        self.lru.extend(still_referenced);
        evicted
    }

    /// Demote LRU entries one rung at a time until `need_hot` hot blocks
    /// have left the hot tier (or nothing more is demotable).  Pops ride
    /// the same lazy heap as eviction, with the same staleness and
    /// still-referenced rules; a demotable snapshot's entry is handed
    /// *owned* to `demote` along with the node's full hash path (ancestor
    /// hashes + own hash — what the spill manifest records), and the
    /// closure returns the next-rung replacement or `None` to drop the
    /// node (ladder exhausted).  A re-armed node keeps its stamp — its
    /// LRU position is unchanged, so later pressure passes keep sinking
    /// it — and is deferred for the rest of *this* pass, so one call
    /// never demotes the same entry twice.  Returns how many hot blocks
    /// were freed (the closure releases their `Arc`s itself).
    pub fn demote_lru_batch<F>(&mut self, need_hot: usize, mut demote: F) -> usize
    where
        F: FnMut(&[u64], CacheEntry) -> Option<CacheEntry>,
    {
        let mut freed_hot = 0;
        let mut deferred: Vec<LruEntry> = Vec::new();
        while freed_hot < need_hot {
            let Some(Reverse((stamp, id))) = self.lru.pop() else {
                break;
            };
            let (was_hot, demotable) = {
                let Some(node) = self.arena[id].as_ref() else {
                    continue; // stale: pruned
                };
                let Some(entry) = node.entry.as_ref() else {
                    continue; // stale: tombstoned
                };
                if node.last_touch != stamp {
                    continue; // stale: re-touched
                }
                if matches!(entry, CacheEntry::Spilled) {
                    continue; // already at the bottom rung: drop the snapshot
                }
                (entry.is_hot(), entry.ram_unreferenced())
            };
            if !demotable {
                deferred.push(Reverse((stamp, id)));
                continue;
            }
            let path = self.path_of(id);
            let owned = self.node_mut(id).entry.take().expect("validated above");
            match demote(&path, owned) {
                Some(colder) => {
                    self.node_mut(id).entry = Some(colder);
                    deferred.push(Reverse((stamp, id)));
                }
                None => {
                    self.entries -= 1;
                    self.prune_up(id);
                }
            }
            if was_hot {
                freed_hot += 1;
            }
        }
        self.lru.extend(deferred);
        freed_hot
    }

    /// Visit every entry-holding node with its full hash path and a
    /// mutable slot — the spill-snapshot walk
    /// ([`KvCache::spill_index`](super::KvCache::spill_index)) swaps
    /// RAM entries for `Spilled` markers in place.  Stamps and the LRU
    /// heap are untouched (a representation swap is not a use).  If the
    /// closure empties a slot the node is dropped and pruned like an
    /// eviction.
    pub fn for_each_entry_mut<F>(&mut self, mut f: F)
    where
        F: FnMut(&[u64], &mut Option<CacheEntry>),
    {
        let ids: Vec<NodeId> = (0..self.arena.len())
            .filter(|&id| {
                id != ROOT && self.arena[id].as_ref().is_some_and(|n| n.entry.is_some())
            })
            .collect();
        for id in ids {
            let path = self.path_of(id);
            let node = self.arena[id].as_mut().expect("listed live above");
            f(&path, &mut node.entry);
            if self.arena[id].as_ref().expect("listed live above").entry.is_none() {
                self.entries -= 1;
                self.prune_up(id);
            }
        }
    }

    /// Remove `id` if it is an empty tombstone, cascading up through
    /// ancestors that become empty tombstones themselves.  Freed slots
    /// go to the free list for reuse.
    fn prune_up(&mut self, mut id: NodeId) {
        while id != ROOT {
            let node = self.node(id);
            if node.entry.is_some() || !node.children.is_empty() {
                break;
            }
            let (parent, key) = (node.parent, node.key);
            self.node_mut(parent).children.remove(&key);
            self.arena[id] = None;
            self.free.push(id);
            id = parent;
        }
    }

    /// The retired full-trie implementation, kept as the test oracle for
    /// the heap path: collect every evictable node in one DFS from the
    /// root, sort by the unique stamps, take the oldest `max`.
    #[cfg(test)]
    fn evict_lru_batch_dfs(&mut self, max: usize) -> Vec<CacheEntry> {
        if max == 0 {
            return Vec::new();
        }
        let mut candidates = Vec::new();
        self.find_evictable(ROOT, &mut candidates);
        // unique stamps make the order (and the evicted set) deterministic
        candidates.sort_unstable_by_key(|(stamp, _)| *stamp);
        candidates.truncate(max);
        let mut evicted = Vec::with_capacity(candidates.len());
        for (_, id) in candidates {
            let node = self.node_mut(id);
            let entry = node.entry.take().expect("evictable node holds an entry");
            self.entries -= 1;
            self.prune_up(id);
            evicted.push(entry);
        }
        evicted
    }

    /// DFS collecting `(last_touch, id)` of every evictable node (RAM
    /// entry held, nothing outside the index referencing it) — oracle
    /// support only.
    #[cfg(test)]
    fn find_evictable(&self, id: NodeId, out: &mut Vec<(u64, NodeId)>) {
        for &child in self.node(id).children.values() {
            let node = self.node(child);
            if let Some(entry) = &node.entry {
                if !matches!(entry, CacheEntry::Spilled) && entry.ram_unreferenced() {
                    out.push((node.last_touch, child));
                }
            }
            self.find_evictable(child, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::tier::{BlockTier, QuantBlock};

    fn sealed(token_elems: usize, fill: f32) -> Arc<KvBlock> {
        let mut b = KvBlock::from_storage(vec![0.0; token_elems], vec![0.0; token_elems], token_elems);
        b.push(&vec![fill; token_elems], &vec![fill * 2.0; token_elems]);
        Arc::new(b)
    }

    fn hot(entry: &CacheEntry) -> &Arc<KvBlock> {
        match entry {
            CacheEntry::Hot(b) => b,
            other => panic!("expected a hot entry, got {other:?}"),
        }
    }

    #[test]
    fn lookup_hits_only_verified_content_at_the_same_path() {
        let mut idx = PrefixIndex::new();
        let b0 = sealed(2, 1.0);
        let h0 = b0.content_hash();
        assert!(idx.insert(&[], h0, CacheEntry::Hot(Arc::clone(&b0))).is_none());
        assert_eq!(idx.len(), 1);
        // same path, same content: hit
        let probe = sealed(2, 1.0);
        let hit = idx.lookup(&[], probe.content_hash(), &probe).expect("hit");
        assert!(Arc::ptr_eq(&hit, &b0));
        // different path (depth 1): miss even with equal content
        assert!(idx.lookup(&[h0], probe.content_hash(), &probe).is_none());
        // unknown hash: miss
        assert!(idx.lookup(&[], h0 ^ 1, &probe).is_none());
    }

    #[test]
    fn probe_then_touch_matches_fused_lookup_stamps() {
        // two indexes given the same op sequence, one through lookup and
        // one through the split probe/touch flow, must evict identically
        let mut fused = PrefixIndex::new();
        let mut split = PrefixIndex::new();
        let blocks: Vec<_> = (0..3).map(|i| sealed(2, i as f32 + 1.0)).collect();
        for b in &blocks {
            let _ = fused.insert(&[], b.content_hash(), CacheEntry::Hot(Arc::clone(b)));
            let _ = split.insert(&[], b.content_hash(), CacheEntry::Hot(Arc::clone(b)));
        }
        // touch block 0 in both (and a miss probe in both, which must
        // also advance the clock identically)
        let probe = sealed(2, 1.0);
        fused.lookup(&[], probe.content_hash(), &probe).expect("fused hit");
        assert!(fused.lookup(&[], 12345, &probe).is_none());
        let id = split.probe(&[], probe.content_hash()).expect("probed");
        assert!(matches!(split.entry_cloned(id), Some(CacheEntry::Hot(_))));
        split.touch_probed(id);
        assert!(split.probe(&[], 12345).is_none());
        drop(blocks);
        for _ in 0..3 {
            let a = fused.evict_lru().expect("fused evictable");
            let b = split.evict_lru().expect("split evictable");
            assert!(hot(&a).content_eq(hot(&b)), "eviction order diverged");
        }
    }

    #[test]
    fn eviction_skips_referenced_blocks() {
        let mut idx = PrefixIndex::new();
        let held = sealed(2, 1.0);
        let loose = sealed(2, 2.0);
        let _ = idx.insert(&[], held.content_hash(), CacheEntry::Hot(Arc::clone(&held))); // 2 refs
        let _ = idx.insert(&[], loose.content_hash(), CacheEntry::Hot(loose)); // 1 ref (index only)
        let evicted = idx.evict_lru().expect("loose block evictable");
        assert_eq!(hot(&evicted).k_token(0)[0], 2.0, "must evict the unreferenced block");
        assert_eq!(idx.len(), 1);
        assert!(idx.evict_lru().is_none(), "held block must never be evicted");
        drop(held);
        assert!(idx.evict_lru().is_some(), "released block becomes evictable");
        assert!(idx.is_empty());
    }

    #[test]
    fn lru_order_follows_touches() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let _ = idx.insert(&[], a.content_hash(), CacheEntry::Hot(Arc::clone(&a)));
        let _ = idx.insert(&[], b.content_hash(), CacheEntry::Hot(Arc::clone(&b)));
        // touch a, making b the LRU
        let probe = sealed(2, 1.0);
        idx.lookup(&[], probe.content_hash(), &probe).expect("hit a");
        drop(a);
        drop(b);
        let evicted = idx.evict_lru().expect("evictable");
        assert_eq!(hot(&evicted).k_token(0)[0], 2.0, "least-recently-touched first");
    }

    #[test]
    fn interior_eviction_tombstones_and_reinsert_rearms() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, CacheEntry::Hot(Arc::clone(&parent)));
        let _ = idx.insert(&[hp], hc, CacheEntry::Hot(Arc::clone(&child)));
        drop(parent); // only the index holds the parent now
        let evicted = idx.evict_lru().expect("parent evictable");
        assert_eq!(hot(&evicted).k_token(0)[0], 1.0);
        assert_eq!(idx.len(), 1);
        // the child stays addressable through the tombstone
        let probe = sealed(2, 2.0);
        let hit = idx.lookup(&[hp], probe.content_hash(), &probe).expect("child survives");
        assert!(Arc::ptr_eq(&hit, &child));
        // re-arming the tombstone counts as one entry again
        let parent2 = sealed(2, 1.0);
        assert!(
            idx.insert(&[], hp, CacheEntry::Hot(parent2)).is_none(),
            "tombstone re-arm displaces nothing"
        );
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn insert_returns_the_displaced_entry() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let h = a.content_hash();
        assert!(idx.insert(&[], h, CacheEntry::Hot(Arc::clone(&a))).is_none());
        // simulated hash collision: different content forced onto the
        // same key must hand the old entry back, not drop it
        let displaced =
            idx.insert(&[], h, CacheEntry::Hot(Arc::clone(&b))).expect("displaced entry returned");
        assert!(Arc::ptr_eq(hot(&displaced), &a));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn remove_if_unshared_respects_other_holders() {
        let mut idx = PrefixIndex::new();
        let block = sealed(2, 1.0);
        let h = block.content_hash();
        let _ = idx.insert(&[], h, CacheEntry::Hot(Arc::clone(&block))); // index + `block` = 2 refs
        let holder = SealedRef::Hot(Arc::clone(&block)); // the chain's ref (3 refs now)
        assert!(idx.remove_if_unshared(&[], h, &holder).is_none(), "shared: must keep");
        drop(block);
        let removed = idx.remove_if_unshared(&[], h, &holder).expect("unshared: removed");
        let SealedRef::Hot(held) = &holder else { unreachable!() };
        assert!(Arc::ptr_eq(hot(&removed), held));
        assert!(idx.is_empty());
    }

    #[test]
    fn batch_eviction_takes_oldest_first_and_retries_referenced() {
        let mut idx = PrefixIndex::new();
        let blocks: Vec<_> = (0..4).map(|i| sealed(2, i as f32 + 1.0)).collect();
        for b in &blocks {
            let _ = idx.insert(&[], b.content_hash(), CacheEntry::Hot(Arc::clone(b)));
        }
        let keep = Arc::clone(&blocks[0]); // oldest stamp, but referenced
        drop(blocks);
        let evicted = idx.evict_lru_batch(2);
        assert_eq!(evicted.len(), 2);
        assert_eq!(hot(&evicted[0]).k_token(0)[0], 2.0, "oldest unreferenced first");
        assert_eq!(hot(&evicted[1]).k_token(0)[0], 3.0);
        assert_eq!(idx.len(), 2);
        drop(keep);
        assert_eq!(idx.evict_lru_batch(10).len(), 2, "remainder evictable once released");
    }

    #[test]
    fn heap_eviction_matches_dfs_oracle_under_random_interleavings() {
        use crate::rng::Rng;
        // two indexes fed the identical op sequence: one evicts through
        // the lazy heap, the other through the retired full-trie DFS.
        // Unique stamps mean there is exactly one correct eviction order,
        // so the two must stay in lockstep through arbitrary
        // insert/touch/release/evict interleavings.
        for trial in 0..8u64 {
            let mut rng = Rng::new(1000 + trial);
            let mut heap_idx = PrefixIndex::new();
            let mut dfs_idx = PrefixIndex::new();
            // parallel holders: same pin/release decisions, separate Arcs
            // per index (so strong counts evolve identically)
            let mut held: Vec<(Arc<KvBlock>, Arc<KvBlock>)> = Vec::new();
            // every insert's (prefix path, hash, fill) — touch targets
            let mut inserted: Vec<(Vec<u64>, u64, f32)> = Vec::new();
            let mut paths: Vec<Vec<u64>> = vec![Vec::new()];
            let mut fill = 0.0f32;
            for _ in 0..300 {
                match rng.below(10) {
                    0..=3 => {
                        // insert a fresh block at a random known prefix
                        fill += 1.0;
                        let path = paths[rng.below(paths.len())].clone();
                        let a = sealed(2, fill);
                        let b = sealed(2, fill);
                        let hash = a.content_hash();
                        let da = heap_idx.insert(&path, hash, CacheEntry::Hot(Arc::clone(&a)));
                        let db = dfs_idx.insert(&path, hash, CacheEntry::Hot(Arc::clone(&b)));
                        assert_eq!(da.is_some(), db.is_some());
                        if rng.below(2) == 0 {
                            held.push((a, b)); // a "live stream" pins it
                        }
                        let mut full = path.clone();
                        full.push(hash);
                        inserted.push((path, hash, fill));
                        paths.push(full);
                    }
                    4..=5 if !inserted.is_empty() => {
                        // touch: re-look-up a previously inserted block
                        let (path, hash, f) = inserted[rng.below(inserted.len())].clone();
                        let probe = sealed(2, f);
                        let ha = heap_idx.lookup(&path, hash, &probe);
                        let hb = dfs_idx.lookup(&path, hash, &probe);
                        assert_eq!(ha.is_some(), hb.is_some(), "hit status diverged");
                    }
                    6 if !held.is_empty() => {
                        // release a held pair: the block becomes evictable
                        let i = rng.below(held.len());
                        held.swap_remove(i);
                    }
                    _ => {
                        let k = 1 + rng.below(3);
                        let got = heap_idx.evict_lru_batch(k);
                        let want = dfs_idx.evict_lru_batch_dfs(k);
                        assert_eq!(got.len(), want.len(), "evicted counts diverged");
                        for (g, w) in got.iter().zip(&want) {
                            assert!(hot(g).content_eq(hot(w)), "eviction order diverged");
                        }
                    }
                }
                assert_eq!(heap_idx.len(), dfs_idx.len(), "entry counts diverged");
            }
            // drain: everything released, the remainders must evict in
            // the same order
            held.clear();
            loop {
                let got = heap_idx.evict_lru_batch(4);
                let want = dfs_idx.evict_lru_batch_dfs(4);
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert!(hot(g).content_eq(hot(w)));
                }
                if got.is_empty() {
                    break;
                }
            }
            assert!(heap_idx.is_empty() && dfs_idx.is_empty());
        }
    }

    #[test]
    fn leaf_eviction_prunes_empty_tombstone_chains() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, CacheEntry::Hot(parent));
        let _ = idx.insert(&[hp], hc, CacheEntry::Hot(child));
        // evict both (insertion order: parent is older)
        assert!(idx.evict_lru().is_some());
        assert!(idx.evict_lru().is_some());
        assert!(idx.is_empty());
        assert!(idx.node(ROOT).children.is_empty(), "tombstone chain must be pruned");
        assert_eq!(idx.free.len(), 2, "pruned nodes return their arena slots");
    }

    #[test]
    fn freed_ids_are_reused_and_stale_snapshots_stay_inert() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let ha = a.content_hash();
        let _ = idx.insert(&[], ha, CacheEntry::Hot(a));
        assert!(idx.evict_lru().is_some());
        let slots_after_evict = idx.arena.len();
        // the freed slot is reused by the next insert — the arena does
        // not grow...
        let b = sealed(2, 2.0);
        let hb = b.content_hash();
        let _ = idx.insert(&[], hb, CacheEntry::Hot(Arc::clone(&b)));
        assert_eq!(idx.arena.len(), slots_after_evict, "freed slot must be reused");
        assert!(idx.free.is_empty());
        // ...and any stale snapshot aimed at the recycled id must not
        // evict (or double-count) the new tenant while it is referenced
        assert!(idx.evict_lru().is_none(), "b is still referenced");
        assert_eq!(idx.len(), 1);
        drop(b);
        let evicted = idx.evict_lru().expect("b evictable after release");
        assert_eq!(hot(&evicted).k_token(0)[0], 2.0);
        assert!(idx.is_empty());
    }

    #[test]
    fn demote_sinks_one_rung_per_pass_and_reports_paths() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let ha = a.content_hash();
        let hb = b.content_hash();
        let _ = idx.insert(&[], ha, CacheEntry::Hot(a)); // index-only
        let _ = idx.insert(&[ha], hb, CacheEntry::Hot(b)); // index-only, child of a
        // pass 1: both hot entries demote exactly one rung, oldest first,
        // with full paths reported
        let mut seen: Vec<Vec<u64>> = Vec::new();
        let freed = idx.demote_lru_batch(2, |path, entry| {
            seen.push(path.to_vec());
            let CacheEntry::Hot(block) = entry else {
                panic!("pass 1 must only see hot entries")
            };
            Some(CacheEntry::Quant(Arc::new(QuantBlock::quantise(&block, BlockTier::F16))))
        });
        assert_eq!(freed, 2);
        assert_eq!(seen, vec![vec![ha], vec![ha, hb]], "oldest first, full paths");
        assert_eq!(idx.len(), 2, "re-armed entries stay counted");
        // pass 2: asking for more hot frees finds none — the quant
        // entries each sink one more rung (here: dropped)
        let freed = idx.demote_lru_batch(1, |_, entry| {
            assert!(matches!(entry, CacheEntry::Quant(_)), "pass 2 sees the quant rung");
            None
        });
        assert_eq!(freed, 0, "no hot blocks left to free");
        assert!(idx.is_empty(), "ladder exhausted: entries dropped and pruned");
    }

    #[test]
    fn demote_skips_referenced_and_spilled_entries() {
        let mut idx = PrefixIndex::new();
        let pinned = sealed(2, 1.0);
        let hp = pinned.content_hash();
        let _ = idx.insert(&[], hp, CacheEntry::Hot(Arc::clone(&pinned))); // 2 refs
        let _ = idx.insert(&[], 0xdead, CacheEntry::Spilled);
        let freed = idx.demote_lru_batch(1, |_, _| panic!("nothing is demotable"));
        assert_eq!(freed, 0);
        assert_eq!(idx.len(), 2, "skipped entries stay");
        // the pinned block stays demotable later (its snapshot was deferred)
        drop(pinned);
        let freed = idx.demote_lru_batch(1, |_, entry| {
            assert!(entry.is_hot());
            None
        });
        assert_eq!(freed, 1);
    }

    #[test]
    fn replace_entry_swaps_representation_in_place() {
        let mut idx = PrefixIndex::new();
        let _ = idx.insert(&[], 0x42, CacheEntry::Spilled);
        let id = idx.probe(&[], 0x42).expect("probed");
        assert!(matches!(idx.entry_cloned(id), Some(CacheEntry::Spilled)));
        let fresh = sealed(2, 3.0);
        let old = idx.replace_entry(id, CacheEntry::Hot(Arc::clone(&fresh)));
        assert!(matches!(old, Some(CacheEntry::Spilled)));
        idx.touch_probed(id);
        assert_eq!(idx.len(), 1, "promotion neither creates nor destroys entries");
        let probe = sealed(2, 3.0);
        let hit = idx.lookup(&[], 0x42, &probe);
        assert!(hit.is_some_and(|h| Arc::ptr_eq(&h, &fresh)), "promoted entry serves hot hits");
    }

    #[test]
    fn for_each_entry_mut_visits_full_paths_and_swaps() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let ha = a.content_hash();
        let hb = b.content_hash();
        let _ = idx.insert(&[], ha, CacheEntry::Hot(a));
        let _ = idx.insert(&[ha], hb, CacheEntry::Hot(b));
        let mut paths = Vec::new();
        idx.for_each_entry_mut(|path, slot| {
            paths.push(path.to_vec());
            *slot = Some(CacheEntry::Spilled); // drop the Arc, keep the entry
        });
        paths.sort();
        assert_eq!(paths, vec![vec![ha], vec![ha, hb]]);
        assert_eq!(idx.len(), 2, "swapped entries stay counted");
        // both are Spilled now: the probe path still resolves them
        let id = idx.probe(&[ha], hb).expect("spilled entries stay addressable");
        assert!(matches!(idx.entry_cloned(id), Some(CacheEntry::Spilled)));
    }
}
