//! The prefix-sharing index: a radix trie over sealed block content
//! hashes.
//!
//! A stream's sealed blocks form a path of content hashes `h₀ h₁ h₂ …`
//! from the trie root; the node at depth `i` holds the shared
//! `Arc<KvBlock>` for the stream's `i`-th block.  Two streams whose
//! prompts share a prefix walk the same hash path and receive the same
//! physical blocks — [`PrefixIndex::lookup`] verifies every hash hit by
//! full content comparison ([`KvBlock::content_eq`]), so a hash collision
//! degrades to a miss, never to shared wrong bytes.
//!
//! **Invariants.**
//!
//! * A node's position encodes its *absolute* prefix path — blocks are
//!   only ever shared between streams whose entire preceding token
//!   sequences were bitwise identical.
//! * Eviction ([`PrefixIndex::evict_lru`]) only ever removes a block with
//!   no holder outside the index (`Arc` strong count 1): a block a live
//!   stream still references is never dropped.
//! * An evicted interior node leaves a block-less *tombstone* so its
//!   descendants stay addressable (a sliding-window stream may drop its
//!   front blocks — unpinning them — while it keeps sealing deeper ones
//!   on the same path); evicted leaves are removed and empty tombstone
//!   chains pruned.
//! * Every insert and every hit stamps a unique logical-clock value, so
//!   LRU selection has no ties and is deterministic regardless of hash-map
//!   iteration order.
//! * **LRU selection is O(log N), not a trie walk.**  Every stamp
//!   assignment also pushes a `(stamp, node id)` snapshot onto a
//!   min-heap; the node's `last_touch` stays the single source of truth,
//!   and a popped snapshot whose stamp no longer matches (the node was
//!   re-touched, evicted, or removed) is simply discarded — *lazy
//!   invalidation*.  A popped entry whose block is still referenced by a
//!   live stream is pushed back and retried on a later eviction pass.
//!   Because stamps are unique, the heap's pop order is a total order,
//!   and the evicted sequence is exactly what a full-trie DFS sorted by
//!   stamp would produce (pinned against the `#[cfg(test)]` DFS oracle
//!   under randomized interleavings).
//! * **Nodes live in an arena of stable ids.**  Trie edges are
//!   `hash → NodeId` and each LRU snapshot is a two-word
//!   `(stamp, NodeId)` — O(1) per snapshot, instead of the retired
//!   owned-path snapshots whose memory was O(Σ depth), quadratic for one
//!   deep chain.  Pruned nodes return their ids to a free list for
//!   reuse; a stale snapshot aimed at a reused id is inert because the
//!   new tenant carries a strictly newer stamp (or no block yet), so the
//!   stamp check rejects it.

use super::block::KvBlock;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

/// Stable arena index of one trie node.
type NodeId = usize;

/// The arena slot of the (block-less, unprunable) root node.
const ROOT: NodeId = 0;

/// One lazy LRU snapshot: the stamp a node carried when it was touched,
/// plus the node's stable arena id — two words, O(1) regardless of trie
/// depth.
type LruEntry = Reverse<(u64, NodeId)>;

#[derive(Debug)]
struct TrieNode {
    /// The shared block, or `None` for a tombstone (evicted interior
    /// node kept only to keep descendants addressable) and for the root.
    block: Option<Arc<KvBlock>>,
    children: HashMap<u64, NodeId>,
    /// Logical-clock stamp of the last insert/hit (unique per node).
    last_touch: u64,
    /// Arena id of the parent (`ROOT` points at itself) — what lets
    /// pruning cascade upward without re-walking a path.
    parent: NodeId,
    /// The hash this node hangs under in its parent's `children`.
    key: u64,
}

/// Radix trie mapping sealed-block hash paths to shared blocks.  See the
/// [module docs](self) for the invariants.
#[derive(Debug)]
pub struct PrefixIndex {
    /// Node arena; slot 0 is the root, `None` slots are on `free`.
    arena: Vec<Option<TrieNode>>,
    /// Freed arena slots awaiting reuse.
    free: Vec<NodeId>,
    clock: u64,
    /// Nodes currently holding a block (tombstones excluded).
    entries: usize,
    /// Min-heap of `(last_touch, node id)` snapshots — the O(log N) LRU.
    /// May hold stale entries (lazy invalidation; see the module docs);
    /// compacted by an arena scan when stale entries dominate.
    lru: BinaryHeap<LruEntry>,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefixIndex {
    pub fn new() -> Self {
        let root = TrieNode {
            block: None,
            children: HashMap::new(),
            last_touch: 0,
            parent: ROOT,
            key: 0,
        };
        Self {
            arena: vec![Some(root)],
            free: Vec::new(),
            clock: 0,
            entries: 0,
            lru: BinaryHeap::new(),
        }
    }

    /// Blocks currently held by the index.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    fn node(&self, id: NodeId) -> &TrieNode {
        self.arena[id].as_ref().expect("live node id")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut TrieNode {
        self.arena[id].as_mut().expect("live node id")
    }

    /// Follow `path` from the root; `None` if any edge is missing.
    fn walk(&self, path: &[u64]) -> Option<NodeId> {
        let mut at = ROOT;
        for h in path {
            at = *self.node(at).children.get(h)?;
        }
        Some(at)
    }

    /// Allocate a fresh tombstone node under `parent`, reusing a freed
    /// arena slot when one exists.
    fn alloc_child(&mut self, parent: NodeId, key: u64) -> NodeId {
        let node = TrieNode {
            block: None,
            children: HashMap::new(),
            last_touch: 0,
            parent,
            key,
        };
        let id = match self.free.pop() {
            Some(id) => {
                self.arena[id] = Some(node);
                id
            }
            None => {
                self.arena.push(Some(node));
                self.arena.len() - 1
            }
        };
        self.node_mut(parent).children.insert(key, id);
        id
    }

    /// Look up a just-sealed block: does a stream whose previous sealed
    /// blocks hashed to `path` already have a shared block with
    /// `candidate`'s contents?  On a verified hit the node is touched
    /// (LRU) and its `Arc` cloned out; hash matches with different
    /// contents are misses.
    pub fn lookup(&mut self, path: &[u64], hash: u64, candidate: &KvBlock) -> Option<Arc<KvBlock>> {
        self.clock += 1;
        let stamp = self.clock;
        let at = self.walk(path)?;
        let id = *self.node(at).children.get(&hash)?;
        let node = self.node_mut(id);
        let block = node.block.as_ref()?;
        if !block.content_eq(candidate) {
            return None; // hash collision: treat as a miss, never share
        }
        let shared = Arc::clone(block);
        node.last_touch = stamp;
        self.push_lru(stamp, id);
        Some(shared)
    }

    /// Register a freshly sealed block at `path` + `hash`.  Missing
    /// intermediate nodes (evicted ancestors of a sliding-window stream)
    /// are recreated as tombstones; an existing tombstone at the target
    /// is re-armed with the block.  The displaced block, if any (a hash
    /// collision overwriting a different-content entry), is returned so
    /// the caller can release it back to the pool — the index never
    /// drops an `Arc` the pool's residency ledger is tracking.
    pub fn insert(&mut self, path: &[u64], hash: u64, block: Arc<KvBlock>) -> Option<Arc<KvBlock>> {
        self.clock += 1;
        let stamp = self.clock;
        let mut at = ROOT;
        for &h in path {
            at = match self.node(at).children.get(&h) {
                Some(&id) => id,
                None => self.alloc_child(at, h),
            };
        }
        let target = match self.node(at).children.get(&hash) {
            Some(&id) => id,
            None => self.alloc_child(at, hash),
        };
        let node = self.node_mut(target);
        let displaced = node.block.take();
        node.block = Some(block);
        node.last_touch = stamp;
        if displaced.is_none() {
            self.entries += 1;
        }
        self.push_lru(stamp, target);
        displaced
    }

    /// Record a fresh `(stamp, node id)` LRU snapshot, compacting the
    /// heap when stale snapshots dominate the live entry count (a long
    /// run of hits with no eviction would otherwise grow it without
    /// bound).
    fn push_lru(&mut self, stamp: u64, id: NodeId) {
        self.lru.push(Reverse((stamp, id)));
        if self.lru.len() > 64 && self.lru.len() > 4 * self.entries.max(1) {
            // rebuild from the arena's current stamps: one snapshot per
            // block-holding node.  Heap pops depend only on the (unique)
            // stamps, so a rebuild never changes the eviction order.
            let mut rebuilt = BinaryHeap::with_capacity(self.entries);
            for (id, slot) in self.arena.iter().enumerate() {
                if let Some(node) = slot {
                    if node.block.is_some() {
                        rebuilt.push(Reverse((node.last_touch, id)));
                    }
                }
            }
            self.lru = rebuilt;
        }
    }

    /// Remove the entry at `path` + `hash` if its block is exactly the
    /// one `holder` shares and nothing else references it (`Arc` strong
    /// count ≤ 2: the index plus `holder`).  Used by the sliding-window
    /// path when no capacity bound exists to reclaim retention later,
    /// and by batch-chain release at request completion.  Returns the
    /// removed `Arc` for the caller to release.
    pub fn remove_if_unshared(
        &mut self,
        path: &[u64],
        hash: u64,
        holder: &Arc<KvBlock>,
    ) -> Option<Arc<KvBlock>> {
        let at = self.walk(path)?;
        let id = *self.node(at).children.get(&hash)?;
        let node = self.node_mut(id);
        let block = node.block.as_ref()?;
        if !Arc::ptr_eq(block, holder) || Arc::strong_count(block) > 2 {
            return None; // another stream still shares it: keep
        }
        let removed = node.block.take().expect("checked above");
        self.entries -= 1;
        self.prune_up(id);
        Some(removed)
    }

    /// Evict the least-recently-touched block that nothing outside the
    /// index references (`Arc` strong count 1), or `None` when every
    /// held block is still referenced elsewhere.
    pub fn evict_lru(&mut self) -> Option<Arc<KvBlock>> {
        self.evict_lru_batch(1).pop()
    }

    /// Evict up to `max` least-recently-touched unreferenced blocks —
    /// O(log N) heap pops per victim instead of a full trie DFS per
    /// sealed block (the steady-state capacity-pressure cost this
    /// replaces).  Snapshots are popped in global stamp order: stale ones
    /// (node gone, tombstoned, re-touched under a newer stamp, or a
    /// freed id's new tenant) are discarded, and snapshots of blocks a
    /// live stream still references are set aside and pushed back for a
    /// later pass.  Interior nodes tombstone (descendants stay
    /// addressable); leaves are removed and empty tombstone chains
    /// pruned.  Returns the evicted `Arc`s for the caller to release
    /// back to the pool, oldest first — possibly fewer than `max`.  The
    /// order matches the `#[cfg(test)]` DFS oracle exactly (unique
    /// stamps leave no ties).
    pub fn evict_lru_batch(&mut self, max: usize) -> Vec<Arc<KvBlock>> {
        let mut evicted = Vec::new();
        let mut still_referenced: Vec<LruEntry> = Vec::new();
        while evicted.len() < max {
            let Some(Reverse((stamp, id))) = self.lru.pop() else {
                break; // heap drained: nothing held is evictable
            };
            let Some(node) = self.arena[id].as_mut() else {
                continue; // stale: the node was evicted and pruned
            };
            let Some(block) = node.block.as_ref() else {
                continue; // stale: tombstoned or removed since the snapshot
            };
            if node.last_touch != stamp {
                continue; // stale: re-touched — a newer snapshot exists
            }
            if Arc::strong_count(block) > 1 {
                // live-referenced: not evictable *now*, but this snapshot
                // is the node's current one — keep it for later passes
                still_referenced.push(Reverse((stamp, id)));
                continue;
            }
            let block = node.block.take().expect("checked above");
            self.entries -= 1;
            self.prune_up(id);
            evicted.push(block);
        }
        self.lru.extend(still_referenced);
        evicted
    }

    /// Remove `id` if it is an empty tombstone, cascading up through
    /// ancestors that become empty tombstones themselves.  Freed slots
    /// go to the free list for reuse.
    fn prune_up(&mut self, mut id: NodeId) {
        while id != ROOT {
            let node = self.node(id);
            if node.block.is_some() || !node.children.is_empty() {
                break;
            }
            let (parent, key) = (node.parent, node.key);
            self.node_mut(parent).children.remove(&key);
            self.arena[id] = None;
            self.free.push(id);
            id = parent;
        }
    }

    /// The retired full-trie implementation, kept as the test oracle for
    /// the heap path: collect every evictable node in one DFS from the
    /// root, sort by the unique stamps, take the oldest `max`.
    #[cfg(test)]
    fn evict_lru_batch_dfs(&mut self, max: usize) -> Vec<Arc<KvBlock>> {
        if max == 0 {
            return Vec::new();
        }
        let mut candidates = Vec::new();
        self.find_evictable(ROOT, &mut candidates);
        // unique stamps make the order (and the evicted set) deterministic
        candidates.sort_unstable_by_key(|(stamp, _)| *stamp);
        candidates.truncate(max);
        let mut evicted = Vec::with_capacity(candidates.len());
        for (_, id) in candidates {
            let node = self.node_mut(id);
            let block = node.block.take().expect("evictable node holds a block");
            self.entries -= 1;
            self.prune_up(id);
            evicted.push(block);
        }
        evicted
    }

    /// DFS collecting `(last_touch, id)` of every evictable node (block
    /// held, strong count 1) — oracle support only.
    #[cfg(test)]
    fn find_evictable(&self, id: NodeId, out: &mut Vec<(u64, NodeId)>) {
        for &child in self.node(id).children.values() {
            let node = self.node(child);
            if let Some(block) = &node.block {
                if Arc::strong_count(block) == 1 {
                    out.push((node.last_touch, child));
                }
            }
            self.find_evictable(child, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sealed(token_elems: usize, fill: f32) -> Arc<KvBlock> {
        let mut b = KvBlock::from_storage(vec![0.0; token_elems], vec![0.0; token_elems], token_elems);
        b.push(&vec![fill; token_elems], &vec![fill * 2.0; token_elems]);
        Arc::new(b)
    }

    #[test]
    fn lookup_hits_only_verified_content_at_the_same_path() {
        let mut idx = PrefixIndex::new();
        let b0 = sealed(2, 1.0);
        let h0 = b0.content_hash();
        assert!(idx.insert(&[], h0, Arc::clone(&b0)).is_none());
        assert_eq!(idx.len(), 1);
        // same path, same content: hit
        let probe = sealed(2, 1.0);
        let hit = idx.lookup(&[], probe.content_hash(), &probe).expect("hit");
        assert!(Arc::ptr_eq(&hit, &b0));
        // different path (depth 1): miss even with equal content
        assert!(idx.lookup(&[h0], probe.content_hash(), &probe).is_none());
        // unknown hash: miss
        assert!(idx.lookup(&[], h0 ^ 1, &probe).is_none());
    }

    #[test]
    fn eviction_skips_referenced_blocks() {
        let mut idx = PrefixIndex::new();
        let held = sealed(2, 1.0);
        let loose = sealed(2, 2.0);
        let _ = idx.insert(&[], held.content_hash(), Arc::clone(&held)); // 2 refs
        let _ = idx.insert(&[], loose.content_hash(), loose); // 1 ref (index only)
        let evicted = idx.evict_lru().expect("loose block evictable");
        assert_eq!(evicted.k_token(0)[0], 2.0, "must evict the unreferenced block");
        assert_eq!(idx.len(), 1);
        assert!(idx.evict_lru().is_none(), "held block must never be evicted");
        drop(held);
        assert!(idx.evict_lru().is_some(), "released block becomes evictable");
        assert!(idx.is_empty());
    }

    #[test]
    fn lru_order_follows_touches() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let _ = idx.insert(&[], a.content_hash(), Arc::clone(&a));
        let _ = idx.insert(&[], b.content_hash(), Arc::clone(&b));
        // touch a, making b the LRU
        let probe = sealed(2, 1.0);
        idx.lookup(&[], probe.content_hash(), &probe).expect("hit a");
        drop(a);
        drop(b);
        let evicted = idx.evict_lru().expect("evictable");
        assert_eq!(evicted.k_token(0)[0], 2.0, "least-recently-touched first");
    }

    #[test]
    fn interior_eviction_tombstones_and_reinsert_rearms() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, Arc::clone(&parent));
        let _ = idx.insert(&[hp], hc, Arc::clone(&child));
        drop(parent); // only the index holds the parent now
        let evicted = idx.evict_lru().expect("parent evictable");
        assert_eq!(evicted.k_token(0)[0], 1.0);
        assert_eq!(idx.len(), 1);
        // the child stays addressable through the tombstone
        let probe = sealed(2, 2.0);
        let hit = idx.lookup(&[hp], probe.content_hash(), &probe).expect("child survives");
        assert!(Arc::ptr_eq(&hit, &child));
        // re-arming the tombstone counts as one entry again
        let parent2 = sealed(2, 1.0);
        assert!(idx.insert(&[], hp, parent2).is_none(), "tombstone re-arm displaces nothing");
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn insert_returns_the_displaced_block() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let b = sealed(2, 2.0);
        let h = a.content_hash();
        assert!(idx.insert(&[], h, Arc::clone(&a)).is_none());
        // simulated hash collision: different content forced onto the
        // same key must hand the old block back, not drop it
        let displaced = idx.insert(&[], h, Arc::clone(&b)).expect("displaced block returned");
        assert!(Arc::ptr_eq(&displaced, &a));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn remove_if_unshared_respects_other_holders() {
        let mut idx = PrefixIndex::new();
        let block = sealed(2, 1.0);
        let h = block.content_hash();
        let _ = idx.insert(&[], h, Arc::clone(&block)); // index + `block` = 2 refs
        let outside = Arc::clone(&block); // a third holder (another stream)
        assert!(idx.remove_if_unshared(&[], h, &block).is_none(), "shared: must keep");
        drop(outside);
        let removed = idx.remove_if_unshared(&[], h, &block).expect("unshared: removed");
        assert!(Arc::ptr_eq(&removed, &block));
        assert!(idx.is_empty());
    }

    #[test]
    fn batch_eviction_takes_oldest_first_and_retries_referenced() {
        let mut idx = PrefixIndex::new();
        let blocks: Vec<_> = (0..4).map(|i| sealed(2, i as f32 + 1.0)).collect();
        for b in &blocks {
            let _ = idx.insert(&[], b.content_hash(), Arc::clone(b));
        }
        let keep = Arc::clone(&blocks[0]); // oldest stamp, but referenced
        drop(blocks);
        let evicted = idx.evict_lru_batch(2);
        assert_eq!(evicted.len(), 2);
        assert_eq!(evicted[0].k_token(0)[0], 2.0, "oldest unreferenced first");
        assert_eq!(evicted[1].k_token(0)[0], 3.0);
        assert_eq!(idx.len(), 2);
        drop(keep);
        assert_eq!(idx.evict_lru_batch(10).len(), 2, "remainder evictable once released");
    }

    #[test]
    fn heap_eviction_matches_dfs_oracle_under_random_interleavings() {
        use crate::rng::Rng;
        // two indexes fed the identical op sequence: one evicts through
        // the lazy heap, the other through the retired full-trie DFS.
        // Unique stamps mean there is exactly one correct eviction order,
        // so the two must stay in lockstep through arbitrary
        // insert/touch/release/evict interleavings.
        for trial in 0..8u64 {
            let mut rng = Rng::new(1000 + trial);
            let mut heap_idx = PrefixIndex::new();
            let mut dfs_idx = PrefixIndex::new();
            // parallel holders: same pin/release decisions, separate Arcs
            // per index (so strong counts evolve identically)
            let mut held: Vec<(Arc<KvBlock>, Arc<KvBlock>)> = Vec::new();
            // every insert's (prefix path, hash, fill) — touch targets
            let mut inserted: Vec<(Vec<u64>, u64, f32)> = Vec::new();
            let mut paths: Vec<Vec<u64>> = vec![Vec::new()];
            let mut fill = 0.0f32;
            for _ in 0..300 {
                match rng.below(10) {
                    0..=3 => {
                        // insert a fresh block at a random known prefix
                        fill += 1.0;
                        let path = paths[rng.below(paths.len())].clone();
                        let a = sealed(2, fill);
                        let b = sealed(2, fill);
                        let hash = a.content_hash();
                        let da = heap_idx.insert(&path, hash, Arc::clone(&a));
                        let db = dfs_idx.insert(&path, hash, Arc::clone(&b));
                        assert_eq!(da.is_some(), db.is_some());
                        if rng.below(2) == 0 {
                            held.push((a, b)); // a "live stream" pins it
                        }
                        let mut full = path.clone();
                        full.push(hash);
                        inserted.push((path, hash, fill));
                        paths.push(full);
                    }
                    4..=5 if !inserted.is_empty() => {
                        // touch: re-look-up a previously inserted block
                        let (path, hash, f) = inserted[rng.below(inserted.len())].clone();
                        let probe = sealed(2, f);
                        let ha = heap_idx.lookup(&path, hash, &probe);
                        let hb = dfs_idx.lookup(&path, hash, &probe);
                        assert_eq!(ha.is_some(), hb.is_some(), "hit status diverged");
                    }
                    6 if !held.is_empty() => {
                        // release a held pair: the block becomes evictable
                        let i = rng.below(held.len());
                        held.swap_remove(i);
                    }
                    _ => {
                        let k = 1 + rng.below(3);
                        let got = heap_idx.evict_lru_batch(k);
                        let want = dfs_idx.evict_lru_batch_dfs(k);
                        assert_eq!(got.len(), want.len(), "evicted counts diverged");
                        for (g, w) in got.iter().zip(&want) {
                            assert!(g.content_eq(w), "eviction order diverged");
                        }
                    }
                }
                assert_eq!(heap_idx.len(), dfs_idx.len(), "entry counts diverged");
            }
            // drain: everything released, the remainders must evict in
            // the same order
            held.clear();
            loop {
                let got = heap_idx.evict_lru_batch(4);
                let want = dfs_idx.evict_lru_batch_dfs(4);
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert!(g.content_eq(w));
                }
                if got.is_empty() {
                    break;
                }
            }
            assert!(heap_idx.is_empty() && dfs_idx.is_empty());
        }
    }

    #[test]
    fn leaf_eviction_prunes_empty_tombstone_chains() {
        let mut idx = PrefixIndex::new();
        let parent = sealed(2, 1.0);
        let child = sealed(2, 2.0);
        let hp = parent.content_hash();
        let hc = child.content_hash();
        let _ = idx.insert(&[], hp, parent);
        let _ = idx.insert(&[hp], hc, child);
        // evict both (insertion order: parent is older)
        assert!(idx.evict_lru().is_some());
        assert!(idx.evict_lru().is_some());
        assert!(idx.is_empty());
        assert!(idx.node(ROOT).children.is_empty(), "tombstone chain must be pruned");
        assert_eq!(idx.free.len(), 2, "pruned nodes return their arena slots");
    }

    #[test]
    fn freed_ids_are_reused_and_stale_snapshots_stay_inert() {
        let mut idx = PrefixIndex::new();
        let a = sealed(2, 1.0);
        let ha = a.content_hash();
        let _ = idx.insert(&[], ha, a);
        assert!(idx.evict_lru().is_some());
        let slots_after_evict = idx.arena.len();
        // the freed slot is reused by the next insert — the arena does
        // not grow...
        let b = sealed(2, 2.0);
        let hb = b.content_hash();
        let _ = idx.insert(&[], hb, Arc::clone(&b));
        assert_eq!(idx.arena.len(), slots_after_evict, "freed slot must be reused");
        assert!(idx.free.is_empty());
        // ...and any stale snapshot aimed at the recycled id must not
        // evict (or double-count) the new tenant while it is referenced
        assert!(idx.evict_lru().is_none(), "b is still referenced");
        assert_eq!(idx.len(), 1);
        drop(b);
        let evicted = idx.evict_lru().expect("b evictable after release");
        assert_eq!(evicted.k_token(0)[0], 2.0);
        assert!(idx.is_empty());
    }
}
