//! Paged KV-cache subsystem: block-paged token storage with prefix
//! sharing and sliding-window eviction.
//!
//! The serving stack's streaming sessions (`attention::AttentionSession`)
//! made decode *compute* cheap; this module makes decode *memory* cheap
//! and shared.  A stream's appended `(K, V)` token rows live in
//! fixed-size [`KvBlock`]s handed out by a [`BlockPool`] and chained into
//! a per-stream [`StreamChain`]:
//!
//! * **Unified ingest.** Every K/V byte enters through one tail-write →
//!   seal → dedupe path, at three granularities: per-token
//!   ([`KvCache::append`]), chunked ([`KvCache::append_chunk`] —
//!   block-sized strides, so sealing/hashing/prefix-lookup amortise per
//!   block; the prefill path), and one-shot batch slabs (the server
//!   opens a [`KvCache::open_batch_stream`] chain per request when
//!   [`KvCacheConfig::batch_dedupe`] is on).  All three are bitwise
//!   interchangeable: the same tokens produce the same blocks, hashes,
//!   and trie paths regardless of ingest granularity.
//! * **Prefix sharing.** When a block fills, its content hash is looked
//!   up in the [`PrefixIndex`] — a radix trie over sealed-block hashes —
//!   and an identical block at the same prefix path is *shared*
//!   (refcounted `Arc`, storage recycled) instead of stored twice.  Two
//!   streams serving the same prompt, a resubmitted decode stream, or a
//!   replayed batched request keep one physical copy of the common
//!   prefix.
//! * **Copy-on-write forks.** [`StreamChain::fork`] clones a chain by
//!   bumping refcounts only; the partially-filled tail block is copied
//!   lazily on the first diverging append.
//! * **Eviction.** [`KvCacheConfig::capacity_blocks`] bounds resident
//!   blocks: at capacity, least-recently-used index entries that no live
//!   stream references are evicted ([`EvictionPolicy::Lru`]) — an
//!   O(log N) heap pop per victim, never a trie walk (see
//!   [`PrefixIndex`]).  [`EvictionPolicy::SlidingWindow`] additionally
//!   bounds each stream to its last `window` tokens, releasing front
//!   blocks as they fall out.
//!
//! **Determinism contract.** The cache deduplicates *storage*, never
//! content: a hash hit is verified by bitwise comparison before sharing,
//! and the token sequence a query observes ([`StreamChain::gather_head_into`])
//! is identical with and without the cache.  Serving through the cache is
//! therefore bitwise identical to serving without it at the same seeds
//! (pinned by `rust/tests/kv_cache.rs`).
//!
//! # Examples
//!
//! ```
//! use skeinformer::kvcache::{KvCache, KvCacheConfig};
//!
//! // 2-token blocks, one f32 per token row, unbounded capacity
//! let mut cache = KvCache::new(KvCacheConfig::new(2), 1);
//! let mut a = cache.open_stream();
//! let mut b = cache.open_stream();
//! for t in 0..4 {
//!     cache.append(&mut a, &[t as f32], &[t as f32]);
//! }
//! for t in 0..4 {
//!     cache.append(&mut b, &[t as f32], &[t as f32]); // same prompt
//! }
//! let stats = cache.stats();
//! assert_eq!(stats.alloc_blocks, 2, "first stream seals two blocks");
//! assert_eq!(stats.hit_blocks, 2, "second stream shares both");
//! ```

mod block;
mod policy;
mod pool;
mod prefix;

pub use block::KvBlock;
pub use policy::{EvictionPolicy, KvCacheConfig};
pub use pool::BlockPool;
pub use prefix::PrefixIndex;

use crate::tensor::Matrix;
use std::collections::VecDeque;
use std::sync::Arc;

/// Aggregate cache counters (see [`KvCache::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Sealed blocks deduplicated against the prefix index (no storage
    /// kept for them beyond the shared copy).
    pub hit_blocks: u64,
    /// Sealed blocks newly inserted into the index.
    pub alloc_blocks: u64,
    /// Blocks evicted from the index: capacity pressure, hash-collision
    /// displacement, or sliding-window drops on an unbounded-capacity
    /// cache.
    pub evicted_blocks: u64,
    /// Distinct blocks currently alive (streams + index), including
    /// per-stream tail blocks.
    pub resident_blocks: u64,
}

/// One stream's view of the cache: retained sealed blocks (shared),
/// the private tail block (copy-on-write when forked), and the window
/// bookkeeping.  Create with [`KvCache::open_stream`], feed through
/// [`KvCache::append`], return with [`KvCache::close_stream`].
#[derive(Debug)]
pub struct StreamChain {
    /// Retained sealed blocks, oldest first; the absolute block index of
    /// `sealed[0]` is `dropped_blocks`.
    sealed: VecDeque<Arc<KvBlock>>,
    /// Content hashes of every sealed block since stream start — the
    /// stream's trie path, kept even for blocks the window released.
    path: Vec<u64>,
    /// Partially filled tail, lazily allocated.
    tail: Option<Arc<KvBlock>>,
    /// Front blocks released under the sliding window.
    dropped_blocks: usize,
    /// Total tokens ever appended.
    appended: usize,
    /// Per-stream copy of the policy window (None = keep everything).
    window: Option<usize>,
    /// Opened by [`KvCache::open_batch_stream`] for a one-shot batch
    /// request: window-exempt while open, and under a window policy its
    /// non-shared blocks are released when the chain closes (see
    /// [`KvCache::close_stream`]).
    is_batch: bool,
    block_size: usize,
    token_elems: usize,
}

impl StreamChain {
    /// Total tokens ever appended (the epoch/seed basis — eviction never
    /// rewinds it).
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// Tokens a query computes over: everything appended, clamped to the
    /// sliding window when one is configured.
    pub fn visible_len(&self) -> usize {
        match self.window {
            Some(w) => self.appended.min(w),
            None => self.appended,
        }
    }

    /// Blocks this chain currently holds (sealed + tail).
    pub fn block_count(&self) -> usize {
        self.sealed.len() + usize::from(self.tail.is_some())
    }

    /// Fork the stream: the clone shares every block by refcount alone.
    /// Both chains copy-on-write the shared tail on their next append, so
    /// neither can observe the other's subsequent tokens.
    pub fn fork(&self) -> StreamChain {
        StreamChain {
            sealed: self.sealed.clone(),
            path: self.path.clone(),
            tail: self.tail.clone(),
            dropped_blocks: self.dropped_blocks,
            appended: self.appended,
            window: self.window,
            is_batch: self.is_batch,
            block_size: self.block_size,
            token_elems: self.token_elems,
        }
    }

    /// The block holding absolute token `t` (which must be visible).
    fn block_for(&self, t: usize) -> (&KvBlock, usize) {
        let b = t / self.block_size;
        let slot = t % self.block_size;
        let rel = b - self.dropped_blocks;
        let block: &KvBlock = if rel < self.sealed.len() {
            &self.sealed[rel]
        } else {
            self.tail.as_ref().expect("visible token beyond sealed blocks lives in the tail")
        };
        (block, slot)
    }

    /// Copy head `head`'s K and V rows for the visible window, oldest
    /// first, into `k_out`/`v_out` (each `visible_len × head_dim`, fully
    /// overwritten).  The row sequence is exactly what an uncached
    /// session accumulated by per-token appends — the identity the
    /// bitwise determinism contract rests on.
    pub fn gather_head_into(
        &self,
        head: usize,
        head_dim: usize,
        k_out: &mut Matrix,
        v_out: &mut Matrix,
    ) {
        let n = self.visible_len();
        assert!(n > 0, "gather on an empty stream");
        let o = head * head_dim;
        assert!(o + head_dim <= self.token_elems, "head {head} out of range");
        assert_eq!(k_out.shape(), (n, head_dim), "k_out shape mismatch");
        assert_eq!(v_out.shape(), (n, head_dim), "v_out shape mismatch");
        let start = self.appended - n;
        for i in 0..n {
            let (block, slot) = self.block_for(start + i);
            k_out.row_mut(i).copy_from_slice(&block.k_token(slot)[o..o + head_dim]);
            v_out.row_mut(i).copy_from_slice(&block.v_token(slot)[o..o + head_dim]);
        }
    }
}

/// The paged KV cache: one [`BlockPool`] + one [`PrefixIndex`] shared by
/// every stream of a server (or any other single-owner serving loop).
/// See the [module docs](self) for the sharing and determinism contract.
#[derive(Debug)]
pub struct KvCache {
    cfg: KvCacheConfig,
    pool: BlockPool,
    index: PrefixIndex,
    hits: u64,
    allocs: u64,
    evictions: u64,
}

impl KvCache {
    /// A cache for streams whose tokens are `token_elems` f32s per K/V
    /// row (the server's `heads * head_dim`).
    pub fn new(cfg: KvCacheConfig, token_elems: usize) -> Self {
        let pool = BlockPool::new(cfg.block_size, token_elems, cfg.capacity_blocks);
        Self { cfg, pool, index: PrefixIndex::new(), hits: 0, allocs: 0, evictions: 0 }
    }

    pub fn cfg(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Open an empty stream chain.
    pub fn open_stream(&mut self) -> StreamChain {
        StreamChain {
            sealed: VecDeque::new(),
            path: Vec::new(),
            tail: None,
            dropped_blocks: 0,
            appended: 0,
            window: self.cfg.window(),
            is_batch: false,
            block_size: self.cfg.block_size,
            token_elems: self.pool.token_elems(),
        }
    }

    /// Open a chain for a one-shot batch-request slab: identical to
    /// [`open_stream`](Self::open_stream) except the sliding window (if
    /// the policy has one) is *not* applied — a batched request has a
    /// fixed `seq` and every token must stay visible for the duration of
    /// its batch.  Under a pure LRU policy, retention of its sealed
    /// blocks after the chain closes is governed by capacity pressure as
    /// usual; under a *window* policy [`close_stream`](Self::close_stream)
    /// releases the chain's non-shared blocks at request completion, so
    /// a burst of one-shot requests cannot pin the pool against windowed
    /// streams.
    pub fn open_batch_stream(&mut self) -> StreamChain {
        let mut chain = self.open_stream();
        chain.window = None;
        chain.is_batch = true;
        chain
    }

    /// Append one token's K and V rows (each `token_elems` long) to a
    /// stream: write into the tail block (copy-on-write if the tail is
    /// shared with a fork), seal + dedupe the block when it fills, and
    /// enforce the sliding window.
    pub fn append(&mut self, chain: &mut StreamChain, k_row: &[f32], v_row: &[f32]) {
        self.ensure_writable_tail(chain);
        let tail = chain.tail.as_mut().expect("tail just ensured");
        Arc::get_mut(tail).expect("tail uniquely owned after CoW").push(k_row, v_row);
        chain.appended += 1;
        if tail.is_full() {
            self.seal_tail(chain);
        }
        self.enforce_window(chain);
    }

    /// Bulk-append a whole chunk of tokens — the chunked-prefill ingest
    /// path.  `k`/`v` are `[heads, tokens, head_dim]` row-major slabs
    /// (the server's request/prefill layout; `heads = token_elems /
    /// head_dim`), written in block-sized strides: the tail
    /// allocation/CoW check runs once per stride and sealing, hashing,
    /// prefix lookup, and window enforcement run once per *block*
    /// instead of once per token.
    ///
    /// **Bitwise identical to the per-token loop**: the block bytes,
    /// hash paths, dedupe hits, LRU stamp order, and window drops are
    /// exactly those of calling [`append`](Self::append) with each
    /// token's gathered `[heads, head_dim]` row in order (pinned in
    /// `rust/tests/kv_cache.rs`, including across window-eviction
    /// boundaries).
    ///
    /// # Panics
    ///
    /// Panics if `head_dim` does not divide the cache's `token_elems` or
    /// the slabs are not exactly `tokens * token_elems` long.
    pub fn append_chunk(
        &mut self,
        chain: &mut StreamChain,
        k: &[f32],
        v: &[f32],
        tokens: usize,
        head_dim: usize,
    ) {
        let te = chain.token_elems;
        assert!(
            head_dim > 0 && te % head_dim == 0,
            "head_dim {head_dim} does not divide token_elems {te}"
        );
        assert_eq!(k.len(), tokens * te, "k chunk slab length mismatch");
        assert_eq!(v.len(), tokens * te, "v chunk slab length mismatch");
        let mut t = 0;
        while t < tokens {
            self.ensure_writable_tail(chain);
            let tail_arc = chain.tail.as_mut().expect("tail just ensured");
            let tail = Arc::get_mut(tail_arc).expect("tail uniquely owned after CoW");
            let take = (tail.block_size() - tail.len()).min(tokens - t);
            for i in t..t + take {
                tail.push_strided(k, v, i, tokens, head_dim);
            }
            chain.appended += take;
            t += take;
            if chain.tail.as_ref().is_some_and(|b| b.is_full()) {
                self.seal_tail(chain);
            }
            // window drops are a pure function of the appended count, so
            // enforcing once per stride lands on the same final state as
            // the per-token loop (no seal/lookup happens in between)
            self.enforce_window(chain);
        }
    }

    /// Make the chain's tail block writable: allocate it if absent, and
    /// copy-on-write if a fork still shares it.  Afterwards
    /// `Arc::get_mut(chain.tail)` is guaranteed to succeed.
    fn ensure_writable_tail(&mut self, chain: &mut StreamChain) {
        if chain.tail.is_none() {
            chain.tail = Some(Arc::new(self.pool.alloc()));
        }
        let tail = chain.tail.as_mut().expect("tail just ensured");
        if Arc::get_mut(tail).is_none() {
            // shared with a fork: copy-on-write before diverging
            let copy = Arc::new(self.pool.cow_clone(tail));
            let shared = std::mem::replace(tail, copy);
            self.pool.release(shared);
        }
    }

    /// Seal the (full) tail: dedupe it against the prefix index or insert
    /// it as a new shared block.
    fn seal_tail(&mut self, chain: &mut StreamChain) {
        let tail = chain.tail.take().expect("seal without a tail");
        debug_assert!(tail.is_full());
        let hash = tail.content_hash();
        if let Some(shared) = self.index.lookup(&chain.path, hash, &tail) {
            chain.sealed.push_back(shared);
            self.pool.release(tail); // staging storage recycled
            self.hits += 1;
        } else {
            // make room for the newly retained block first — O(log N)
            // heap pops for however many evictions the deficit needs
            if self.pool.at_capacity() {
                let over = self.pool.resident() + 1 - self.cfg.capacity_blocks;
                for block in self.index.evict_lru_batch(over) {
                    self.pool.release(block);
                    self.evictions += 1;
                }
                // anything still over capacity is referenced by live
                // streams: the cap is exceeded softly
            }
            if let Some(displaced) = self.index.insert(&chain.path, hash, Arc::clone(&tail)) {
                // hash-collision overwrite: route the displaced Arc
                // through the pool so the residency ledger stays exact
                self.pool.release(displaced);
                self.evictions += 1;
            }
            chain.sealed.push_back(tail);
            self.allocs += 1;
        }
        chain.path.push(hash);
    }

    /// Release sealed front blocks that fell fully outside the window.
    /// With no capacity bound configured there is no later LRU pass to
    /// reclaim index retention, so the index's clone is dropped eagerly
    /// too (unless another stream still shares the block) — a windowed
    /// stream's resident footprint stays O(window), not O(total tokens).
    fn enforce_window(&mut self, chain: &mut StreamChain) {
        let Some(w) = chain.window else {
            return;
        };
        let first_needed_block = chain.appended.saturating_sub(w) / chain.block_size;
        while chain.dropped_blocks < first_needed_block {
            let Some(front) = chain.sealed.pop_front() else {
                break;
            };
            if self.cfg.capacity_blocks == 0 {
                let path = &chain.path[..chain.dropped_blocks];
                let hash = chain.path[chain.dropped_blocks];
                if let Some(evicted) = self.index.remove_if_unshared(path, hash, &front) {
                    self.pool.release(evicted);
                    self.evictions += 1;
                }
            }
            self.pool.release(front);
            chain.dropped_blocks += 1;
        }
    }

    /// Close a stream, releasing its blocks.  Sealed blocks the prefix
    /// index retains stay resident (a resubmitted prompt still hits) until
    /// capacity pressure evicts them — except for a *batch* chain under a
    /// *window* policy: batch chains are window-exempt while open and a
    /// window policy may have no capacity bound (so no later LRU pass),
    /// which would let a burst of one-shot batch requests pin the pool
    /// indefinitely.  For that combination the chain's sealed blocks that
    /// no other live stream shares are removed from the index and
    /// released here, at request completion (counted in
    /// [`KvCacheStats::evicted_blocks`]); blocks a live stream still
    /// shares are kept.
    pub fn close_stream(&mut self, chain: StreamChain) {
        if chain.is_batch && self.cfg.window().is_some() {
            // batch chains never drop front blocks (window-exempt), so
            // sealed[i]'s trie position is exactly path[..i] + path[i]
            debug_assert_eq!(chain.dropped_blocks, 0);
            for (i, block) in chain.sealed.iter().enumerate() {
                if let Some(evicted) =
                    self.index.remove_if_unshared(&chain.path[..i], chain.path[i], block)
                {
                    self.pool.release(evicted);
                    self.evictions += 1;
                }
            }
        }
        for block in chain.sealed {
            self.pool.release(block);
        }
        if let Some(tail) = chain.tail {
            self.pool.release(tail);
        }
    }

    /// Aggregate counters (monotonic except `resident_blocks`).
    pub fn stats(&self) -> KvCacheStats {
        KvCacheStats {
            hit_blocks: self.hits,
            alloc_blocks: self.allocs,
            evicted_blocks: self.evictions,
            resident_blocks: self.pool.resident() as u64,
        }
    }

    /// Lifetime block allocations that touched the heap (the pool's free
    /// list was empty) — see [`BlockPool::fresh_allocs`].  A replayed
    /// prompt or resubmitted batch slab leaves this flat.
    pub fn fresh_allocs(&self) -> u64 {
        self.pool.fresh_allocs()
    }

    /// Resident KV bytes: blocks × block_size × token_elems × (K + V) × 4.
    pub fn resident_kv_bytes(&self) -> u64 {
        self.pool.resident() as u64
            * self.cfg.block_size as u64
            * self.pool.token_elems() as u64
            * 2
            * std::mem::size_of::<f32>() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(cache: &mut KvCache, chain: &mut StreamChain, tokens: std::ops::Range<usize>) {
        for t in tokens {
            let row = vec![t as f32, -(t as f32)];
            cache.append(chain, &row, &row);
        }
    }

    fn cache(block_size: usize) -> KvCache {
        KvCache::new(KvCacheConfig::new(block_size), 2)
    }

    #[test]
    fn shared_prefix_allocates_once() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..6);
        assert_eq!(c.stats().alloc_blocks, 3);
        assert_eq!(c.stats().hit_blocks, 0);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 0..6);
        let s = c.stats();
        assert_eq!(s.alloc_blocks, 3, "replayed prefix must not allocate");
        assert_eq!(s.hit_blocks, 3);
        // diverging suffix allocates again
        fill(&mut c, &mut b, 10..12);
        assert_eq!(c.stats().alloc_blocks, 4);
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn diverging_streams_do_not_share() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        let mut b = c.open_stream();
        fill(&mut c, &mut a, 0..2);
        fill(&mut c, &mut b, 5..7);
        // same second block contents, but different prefix path: no share
        fill(&mut c, &mut a, 100..102);
        fill(&mut c, &mut b, 100..102);
        assert_eq!(c.stats().hit_blocks, 0);
        assert_eq!(c.stats().alloc_blocks, 4);
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn gather_reproduces_append_order() {
        let mut c = cache(3);
        let mut chain = c.open_stream();
        fill(&mut c, &mut chain, 0..7); // 2 sealed blocks + 1 tail token
        assert_eq!(chain.visible_len(), 7);
        let mut k = Matrix::zeros(7, 1);
        let mut v = Matrix::zeros(7, 1);
        // head 1 of head_dim 1: the second element of each token row
        chain.gather_head_into(1, 1, &mut k, &mut v);
        for t in 0..7 {
            assert_eq!(k.get(t, 0), -(t as f32), "token {t}");
        }
        c.close_stream(chain);
    }

    #[test]
    fn fork_is_copy_on_write() {
        let mut c = cache(4);
        let mut parent = c.open_stream();
        fill(&mut c, &mut parent, 0..6); // 1 sealed + 2 tail tokens
        let resident_before = c.stats().resident_blocks;
        let mut child = parent.fork();
        assert_eq!(c.stats().resident_blocks, resident_before, "fork allocates nothing");
        // diverge the child; the parent's tail must be unaffected
        c.append(&mut child, &[99.0, 99.0], &[99.0, 99.0]);
        let mut pk = Matrix::zeros(6, 2);
        let mut pv = Matrix::zeros(6, 2);
        parent.gather_head_into(0, 2, &mut pk, &mut pv);
        assert_eq!(pk.get(5, 0), 5.0, "parent tail unchanged after child append");
        let mut ck = Matrix::zeros(7, 2);
        let mut cv = Matrix::zeros(7, 2);
        child.gather_head_into(0, 2, &mut ck, &mut cv);
        assert_eq!(ck.get(6, 0), 99.0);
        assert_eq!(ck.get(5, 0), 5.0, "shared prefix preserved in the fork");
        c.close_stream(parent);
        c.close_stream(child);
        assert_eq!(c.stats().resident_blocks, 1, "only the sealed (indexed) block remains");
    }

    #[test]
    fn sliding_window_releases_front_blocks() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 2);
        let mut chain = c.open_stream();
        fill(&mut c, &mut chain, 0..10);
        assert_eq!(chain.appended(), 10);
        assert_eq!(chain.visible_len(), 4);
        // tokens 0..6 are outside the window: blocks 0-2 dropped
        assert_eq!(chain.block_count(), 2);
        // no capacity bound configured, so index retention of the
        // dropped (unshared) blocks is released eagerly: resident stays
        // O(window), not O(appended)
        assert_eq!(c.stats().evicted_blocks, 3);
        assert_eq!(c.stats().resident_blocks, 2);
        let mut k = Matrix::zeros(4, 2);
        let mut v = Matrix::zeros(4, 2);
        chain.gather_head_into(0, 2, &mut k, &mut v);
        for (i, t) in (6..10).enumerate() {
            assert_eq!(k.get(i, 0), t as f32, "window row {i}");
        }
        c.close_stream(chain);
    }

    #[test]
    fn window_drop_keeps_blocks_another_stream_shares() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 2);
        let mut a = c.open_stream();
        let mut b = c.open_stream();
        fill(&mut c, &mut a, 0..4); // 2 sealed, all inside the window
        fill(&mut c, &mut b, 0..4); // shares both
        // stream a outgrows the window; its front block is still shared
        // with b, so the index keeps it and b stays fully readable
        fill(&mut c, &mut a, 4..8);
        let mut k = Matrix::zeros(4, 2);
        let mut v = Matrix::zeros(4, 2);
        b.gather_head_into(0, 2, &mut k, &mut v);
        for t in 0..4 {
            assert_eq!(k.get(t, 0), t as f32, "shared block must survive a's window");
        }
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn capacity_evicts_only_unreferenced_blocks() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_capacity_blocks(3), 2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..6); // 3 sealed blocks: at capacity
        // a new stream needs blocks; everything is referenced by `a`, so
        // nothing is evicted and the cap is exceeded softly
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 50..52);
        assert_eq!(c.stats().evicted_blocks, 0);
        assert!(c.stats().resident_blocks > 3);
        c.close_stream(a);
        // now a's blocks are index-only; further sealing evicts LRU ones
        fill(&mut c, &mut b, 52..56);
        assert!(c.stats().evicted_blocks > 0);
        c.close_stream(b);
    }

    /// Build `[heads, tokens, head_dim]` chunk slabs whose token rows
    /// are `fill(t)` — the gathered per-token row of token `t`.
    fn chunk_slabs(
        range: std::ops::Range<usize>,
        heads: usize,
        head_dim: usize,
        fill: impl Fn(usize) -> Vec<f32>,
    ) -> (Vec<f32>, Vec<f32>) {
        let tokens = range.len();
        let mut k = vec![0.0f32; tokens * heads * head_dim];
        for (i, t) in range.enumerate() {
            let row = fill(t);
            for h in 0..heads {
                let dst = h * tokens * head_dim + i * head_dim;
                k[dst..dst + head_dim].copy_from_slice(&row[h * head_dim..(h + 1) * head_dim]);
            }
        }
        (k.clone(), k)
    }

    #[test]
    fn append_chunk_is_bitwise_identical_to_per_token_appends() {
        // 13 tokens through chunks {4, 6, 3} vs one-at-a-time, sliding
        // window 5 at block size 2: strides cross both block seals and
        // window-eviction boundaries
        let row = |t: usize| vec![t as f32, -(t as f32)];
        let mut per_tok = KvCache::new(KvCacheConfig::new(2).with_window(5), 2);
        let mut chunked = KvCache::new(KvCacheConfig::new(2).with_window(5), 2);
        let mut a = per_tok.open_stream();
        let mut b = chunked.open_stream();
        for t in 0..13 {
            let r = row(t);
            per_tok.append(&mut a, &r, &r);
        }
        for range in [0..4, 4..10, 10..13] {
            // heads = 2, head_dim = 1 (token_elems = 2)
            let (k, v) = chunk_slabs(range.clone(), 2, 1, row);
            chunked.append_chunk(&mut b, &k, &v, range.len(), 1);
        }
        assert_eq!(a.appended(), b.appended());
        assert_eq!(a.visible_len(), b.visible_len());
        assert_eq!(a.block_count(), b.block_count());
        let gather = |chain: &StreamChain| {
            let n = chain.visible_len();
            let mut k = Matrix::zeros(n, 2);
            let mut v = Matrix::zeros(n, 2);
            chain.gather_head_into(0, 2, &mut k, &mut v);
            (k, v)
        };
        let (ka, va) = gather(&a);
        let (kb, vb) = gather(&b);
        assert_eq!(ka.max_abs_diff(&kb), 0.0, "chunked K diverged from per-token");
        assert_eq!(va.max_abs_diff(&vb), 0.0, "chunked V diverged from per-token");
        let (sa, sb) = (per_tok.stats(), chunked.stats());
        assert_eq!(sa.alloc_blocks, sb.alloc_blocks);
        assert_eq!(sa.hit_blocks, sb.hit_blocks);
        assert_eq!(sa.evicted_blocks, sb.evicted_blocks);
        assert_eq!(sa.resident_blocks, sb.resident_blocks);
        per_tok.close_stream(a);
        chunked.close_stream(b);
    }

    #[test]
    fn append_chunk_dedupes_against_per_token_ingest() {
        // a chunked replay of a per-token-ingested prompt must hit every
        // sealed block — the two granularities share one hash path
        let row = |t: usize| vec![t as f32, t as f32 + 0.5];
        let mut c = cache(2);
        let mut a = c.open_stream();
        for t in 0..6 {
            let r = row(t);
            c.append(&mut a, &r, &r);
        }
        assert_eq!(c.stats().alloc_blocks, 3);
        let mut b = c.open_stream();
        let (k, v) = chunk_slabs(0..6, 1, 2, row);
        c.append_chunk(&mut b, &k, &v, 6, 2);
        let s = c.stats();
        assert_eq!(s.alloc_blocks, 3, "chunked replay must not allocate");
        assert_eq!(s.hit_blocks, 3, "chunked replay shares every sealed block");
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn batch_stream_ignores_the_window() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 1);
        let mut chain = c.open_batch_stream();
        for t in 0..10 {
            c.append(&mut chain, &[t as f32], &[t as f32]);
        }
        assert_eq!(chain.visible_len(), 10, "batch chains keep the full request");
        assert_eq!(c.stats().evicted_blocks, 0);
        c.close_stream(chain);
    }

    #[test]
    fn batch_chain_close_returns_residency_to_baseline_under_a_window() {
        // --kv-batch-dedupe + --kv-window: batch chains are window-exempt
        // while open, and the window policy has no capacity bound, so
        // without release-at-completion a burst of one-shot requests
        // would pin the pool indefinitely
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4).with_batch_dedupe(true), 1);
        let baseline = c.stats().resident_blocks;
        for burst in 0..5 {
            let mut chain = c.open_batch_stream();
            for t in 0..8 {
                let x = (burst * 8 + t) as f32; // distinct content per request
                c.append(&mut chain, &[x], &[x]);
            }
            assert_eq!(chain.visible_len(), 8, "batch chains stay window-exempt");
            c.close_stream(chain);
        }
        assert_eq!(
            c.stats().resident_blocks,
            baseline,
            "batch burst must not pin the pool"
        );
        assert_eq!(c.stats().evicted_blocks, 20, "4 sealed blocks released per request");

        // a block shared with a live stream survives the batch close
        let mut live = c.open_stream();
        for t in 0..2 {
            c.append(&mut live, &[t as f32], &[t as f32]);
        }
        let mut batch = c.open_batch_stream();
        for t in 0..2 {
            c.append(&mut batch, &[t as f32], &[t as f32]);
        }
        assert_eq!(c.stats().hit_blocks, 1, "batch chain shares the live stream's block");
        c.close_stream(batch);
        let mut k = Matrix::zeros(2, 1);
        let mut v = Matrix::zeros(2, 1);
        live.gather_head_into(0, 1, &mut k, &mut v);
        assert_eq!(k.get(0, 0), 0.0, "shared block must survive the batch close");
        assert_eq!(k.get(1, 0), 1.0);
        c.close_stream(live);
    }

    #[test]
    fn closed_stream_prefix_still_hits() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..4);
        c.close_stream(a);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 0..4);
        assert_eq!(c.stats().hit_blocks, 2, "resubmitted prompt hits after close");
        c.close_stream(b);
    }
}
