//! Paged KV-cache subsystem: block-paged token storage with prefix
//! sharing and sliding-window eviction.
//!
//! The serving stack's streaming sessions (`attention::AttentionSession`)
//! made decode *compute* cheap; this module makes decode *memory* cheap
//! and shared.  A stream's appended `(K, V)` token rows live in
//! fixed-size [`KvBlock`]s handed out by a [`BlockPool`] and chained into
//! a per-stream [`StreamChain`]:
//!
//! * **Unified ingest.** Every K/V byte enters through one tail-write →
//!   seal → dedupe path, at three granularities: per-token
//!   ([`KvCache::append`]), chunked ([`KvCache::append_chunk`] —
//!   block-sized strides, so sealing/hashing/prefix-lookup amortise per
//!   block; the prefill path), and one-shot batch slabs (the server
//!   opens a [`KvCache::open_batch_stream`] chain per request when
//!   [`KvCacheConfig::batch_dedupe`] is on).  All three are bitwise
//!   interchangeable: the same tokens produce the same blocks, hashes,
//!   and trie paths regardless of ingest granularity.
//! * **Prefix sharing.** When a block fills, its content hash is looked
//!   up in the [`PrefixIndex`] — a radix trie over sealed-block hashes —
//!   and an identical block at the same prefix path is *shared*
//!   (refcounted `Arc`, storage recycled) instead of stored twice.  Two
//!   streams serving the same prompt, a resubmitted decode stream, or a
//!   replayed batched request keep one physical copy of the common
//!   prefix.
//! * **Copy-on-write forks.** [`StreamChain::fork`] clones a chain by
//!   bumping refcounts only; the partially-filled tail block is copied
//!   lazily on the first diverging append.
//! * **Eviction.** [`KvCacheConfig::capacity_blocks`] bounds resident
//!   blocks: at capacity, least-recently-used index entries that no live
//!   stream references are evicted ([`EvictionPolicy::Lru`]) — an
//!   O(log N) heap pop per victim, never a trie walk (see
//!   [`PrefixIndex`]).  [`EvictionPolicy::SlidingWindow`] additionally
//!   bounds each stream to its last `window` tokens, releasing front
//!   blocks as they fall out.
//! * **Tiered demotion.** With a [`TierLadder`] configured
//!   ([`KvCacheConfig::tiers`]), capacity pressure demotes LRU
//!   index-only blocks one rung at a time — f32 → f16 → int8 →
//!   spilled-to-disk — instead of dropping them.  Exact f32 bytes are
//!   archived to the content-addressed [`BlockStore`] at *first*
//!   demotion, so a block that sinks to the spilled rung always
//!   rehydrates bitwise identical; every spill read re-verifies the
//!   content digest, and any corruption degrades to a clean miss
//!   ([`KvCacheStats::spill_corrupt`]).  A spill directory also gives
//!   warm restarts ([`KvCache::new`] re-registers the store's manifest)
//!   and cross-process sharing (two caches over one directory).
//!
//! **Determinism contract.** The cache deduplicates *storage*, never
//! content: a hash hit is verified by bitwise comparison before sharing,
//! and the token sequence a query observes ([`StreamChain::gather_head_into`])
//! is identical with and without the cache.  Serving through the cache is
//! therefore bitwise identical to serving without it at the same seeds
//! (pinned by `rust/tests/kv_cache.rs`).  With tiers *disabled* (the
//! default) every byte, hash, stamp, and stat is bitwise identical to the
//! pre-tier implementation; with quantised rungs enabled, a replayed
//! prefix whose blocks were demoted is served through
//! [`QuantBlock::dequant_head_into`] with the documented error bounds
//! (pinned by `rust/tests/kv_tiers.rs`) — an explicitly opted-into
//! approximation, the same trade the paper's sketched attention makes.
//!
//! # Examples
//!
//! ```
//! use skeinformer::kvcache::{KvCache, KvCacheConfig};
//!
//! // 2-token blocks, one f32 per token row, unbounded capacity
//! let mut cache = KvCache::new(KvCacheConfig::new(2), 1);
//! let mut a = cache.open_stream();
//! let mut b = cache.open_stream();
//! for t in 0..4 {
//!     cache.append(&mut a, &[t as f32], &[t as f32]);
//! }
//! for t in 0..4 {
//!     cache.append(&mut b, &[t as f32], &[t as f32]); // same prompt
//! }
//! let stats = cache.stats();
//! assert_eq!(stats.alloc_blocks, 2, "first stream seals two blocks");
//! assert_eq!(stats.hit_blocks, 2, "second stream shares both");
//! ```

mod block;
mod policy;
mod pool;
mod prefix;
mod store;
mod tier;

pub use block::KvBlock;
pub use policy::{EvictionPolicy, KvCacheConfig};
pub use pool::BlockPool;
pub use prefix::PrefixIndex;
pub use store::{tempdir, BlockStore, ManifestEntry, SpillError, TempDir};
pub use tier::{
    f16_bits_to_f32, f32_to_f16_bits, BlockTier, CacheEntry, QuantBlock, SealedRef, TierLadder,
};

use crate::tensor::Matrix;
use std::collections::VecDeque;
use std::sync::Arc;

/// Aggregate cache counters (see [`KvCache::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Sealed blocks deduplicated against the prefix index (no storage
    /// kept for them beyond the shared copy).
    pub hit_blocks: u64,
    /// Sealed blocks newly inserted into the index.
    pub alloc_blocks: u64,
    /// Blocks evicted from the index: capacity pressure, hash-collision
    /// displacement, or sliding-window drops on an unbounded-capacity
    /// cache.
    pub evicted_blocks: u64,
    /// Distinct blocks currently alive (streams + index), including
    /// per-stream tail blocks.
    pub resident_blocks: u64,
    /// Blocks currently resident in a quantised (f16/int8)
    /// representation — counted separately from `resident_blocks`,
    /// which tracks hot f32 blocks only.
    pub quant_blocks: u64,
    /// Demotions performed, one per rung descended (f32 → f16,
    /// f16 → int8).
    pub demoted_blocks: u64,
    /// Entries demoted to the disk-only spilled rung (RAM payload
    /// released; exact bytes remain in the [`BlockStore`]).
    pub spilled_blocks: u64,
    /// Seal-time hash hits served by rehydrating (and re-verifying) an
    /// archived block from the spill store.
    pub spill_hits: u64,
    /// Spill reads that failed verification — truncated file, digest
    /// mismatch, missing file — and degraded to clean misses.
    pub spill_corrupt: u64,
}

/// One stream's view of the cache: retained sealed blocks (shared),
/// the private tail block (copy-on-write when forked), and the window
/// bookkeeping.  Create with [`KvCache::open_stream`], feed through
/// [`KvCache::append`], return with [`KvCache::close_stream`].
#[derive(Debug)]
pub struct StreamChain {
    /// Retained sealed blocks, oldest first; the absolute block index of
    /// `sealed[0]` is `dropped_blocks`.  Each is hot (exact f32) or
    /// quantised — never spilled: holding a [`SealedRef`] pins the
    /// payload in RAM (see [`SealedRef`]), which keeps gathers free of
    /// disk I/O.
    sealed: VecDeque<SealedRef>,
    /// Content hashes of every sealed block since stream start — the
    /// stream's trie path, kept even for blocks the window released.
    path: Vec<u64>,
    /// Partially filled tail, lazily allocated.
    tail: Option<Arc<KvBlock>>,
    /// Front blocks released under the sliding window.
    dropped_blocks: usize,
    /// Total tokens ever appended.
    appended: usize,
    /// Per-stream copy of the policy window (None = keep everything).
    window: Option<usize>,
    /// Opened by [`KvCache::open_batch_stream`] for a one-shot batch
    /// request: window-exempt while open, and under a window policy its
    /// non-shared blocks are released when the chain closes (see
    /// [`KvCache::close_stream`]).
    is_batch: bool,
    block_size: usize,
    token_elems: usize,
}

impl StreamChain {
    /// Total tokens ever appended (the epoch/seed basis — eviction never
    /// rewinds it).
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// Tokens a query computes over: everything appended, clamped to the
    /// sliding window when one is configured.
    pub fn visible_len(&self) -> usize {
        match self.window {
            Some(w) => self.appended.min(w),
            None => self.appended,
        }
    }

    /// Blocks this chain currently holds (sealed + tail).
    pub fn block_count(&self) -> usize {
        self.sealed.len() + usize::from(self.tail.is_some())
    }

    /// Fork the stream: the clone shares every block by refcount alone.
    /// Both chains copy-on-write the shared tail on their next append, so
    /// neither can observe the other's subsequent tokens.
    pub fn fork(&self) -> StreamChain {
        StreamChain {
            sealed: self.sealed.clone(),
            path: self.path.clone(),
            tail: self.tail.clone(),
            dropped_blocks: self.dropped_blocks,
            appended: self.appended,
            window: self.window,
            is_batch: self.is_batch,
            block_size: self.block_size,
            token_elems: self.token_elems,
        }
    }

    /// The stream's trie path: content hashes of every sealed block
    /// since stream start (kept even for window-dropped blocks).  The
    /// spill-store fault-injection tests use this to address block files.
    pub fn path(&self) -> &[u64] {
        &self.path
    }

    /// Copy head `head`'s K and V rows for the visible window, oldest
    /// first, into `k_out`/`v_out` (each `visible_len × head_dim`, fully
    /// overwritten).  Hot blocks copy their exact f32 rows — with tiers
    /// off, the row sequence is exactly what an uncached session
    /// accumulated by per-token appends, the identity the bitwise
    /// determinism contract rests on.  Quantised blocks (shared from a
    /// demoted index entry at seal time) decode straight into the
    /// caller's scratch rows via [`QuantBlock::dequant_head_into`]; the
    /// decoded f32 view lives only as long as those scratch matrices and
    /// is never cached or re-hashed.  Never touches disk (see
    /// [`SealedRef`]).
    pub fn gather_head_into(
        &self,
        head: usize,
        head_dim: usize,
        k_out: &mut Matrix,
        v_out: &mut Matrix,
    ) {
        let n = self.visible_len();
        assert!(n > 0, "gather on an empty stream");
        let o = head * head_dim;
        assert!(o + head_dim <= self.token_elems, "head {head} out of range");
        assert_eq!(k_out.shape(), (n, head_dim), "k_out shape mismatch");
        assert_eq!(v_out.shape(), (n, head_dim), "v_out shape mismatch");
        let start = self.appended - n;
        for i in 0..n {
            let t = start + i;
            let slot = t % self.block_size;
            let rel = t / self.block_size - self.dropped_blocks;
            if rel < self.sealed.len() {
                match &self.sealed[rel] {
                    SealedRef::Hot(block) => {
                        k_out.row_mut(i).copy_from_slice(&block.k_token(slot)[o..o + head_dim]);
                        v_out.row_mut(i).copy_from_slice(&block.v_token(slot)[o..o + head_dim]);
                    }
                    SealedRef::Quant(q) => {
                        q.dequant_head_into(slot, o, head_dim, k_out.row_mut(i), v_out.row_mut(i));
                    }
                }
            } else {
                let tail = self
                    .tail
                    .as_ref()
                    .expect("visible token beyond sealed blocks lives in the tail");
                k_out.row_mut(i).copy_from_slice(&tail.k_token(slot)[o..o + head_dim]);
                v_out.row_mut(i).copy_from_slice(&tail.v_token(slot)[o..o + head_dim]);
            }
        }
    }
}

/// The paged KV cache: one [`BlockPool`] + one [`PrefixIndex`] shared by
/// every stream of a server (or any other single-owner serving loop).
/// See the [module docs](self) for the sharing and determinism contract.
#[derive(Debug)]
pub struct KvCache {
    cfg: KvCacheConfig,
    pool: BlockPool,
    index: PrefixIndex,
    /// The spill tier's on-disk archive; `Some` iff the ladder has a
    /// spill directory and it could be opened.
    store: Option<BlockStore>,
    hits: u64,
    allocs: u64,
    evictions: u64,
    demotions: u64,
    spills: u64,
    spill_hits: u64,
    spill_corrupt: u64,
    /// Spill-store writes that failed (disk full, permissions); the
    /// block stays at its current rung instead of spilling.
    spill_write_errors: u64,
}

impl KvCache {
    /// A cache for streams whose tokens are `token_elems` f32s per K/V
    /// row (the server's `heads * head_dim`).
    ///
    /// When the ladder has a spill directory, the store's manifest is
    /// loaded and every archived entry whose geometry matches
    /// (`token_elems`, `block_size`) is re-registered at its trie
    /// position as a spilled entry — a **warm restart**: replaying a
    /// previously spilled prefix rehydrates its blocks from disk instead
    /// of re-allocating them.  Two live caches over one directory share
    /// blocks the same way, across processes.  A store that cannot be
    /// opened disables the spill rung (with a note on stderr) rather
    /// than failing the cache.
    pub fn new(cfg: KvCacheConfig, token_elems: usize) -> Self {
        let pool = BlockPool::new(cfg.block_size, token_elems, cfg.capacity_blocks);
        let mut index = PrefixIndex::new();
        let store = cfg.tiers.spill_dir.as_ref().and_then(|dir| match BlockStore::open(dir) {
            Ok(store) => Some(store),
            Err(e) => {
                eprintln!(
                    "kvcache: disabling spill tier (cannot open store at {}: {e})",
                    dir.display()
                );
                None
            }
        });
        if let Some(store) = &store {
            for entry in store.load_manifest() {
                if entry.token_elems == token_elems
                    && entry.len == cfg.block_size
                    && store.contains(entry.hash)
                {
                    // duplicates collapse: a displaced entry here can only
                    // be another Spilled marker, which holds no payload
                    let _ = index.insert(&entry.path, entry.hash, CacheEntry::Spilled);
                }
            }
        }
        Self {
            cfg,
            pool,
            index,
            store,
            hits: 0,
            allocs: 0,
            evictions: 0,
            demotions: 0,
            spills: 0,
            spill_hits: 0,
            spill_corrupt: 0,
            spill_write_errors: 0,
        }
    }

    pub fn cfg(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Open an empty stream chain.
    pub fn open_stream(&mut self) -> StreamChain {
        StreamChain {
            sealed: VecDeque::new(),
            path: Vec::new(),
            tail: None,
            dropped_blocks: 0,
            appended: 0,
            window: self.cfg.window(),
            is_batch: false,
            block_size: self.cfg.block_size,
            token_elems: self.pool.token_elems(),
        }
    }

    /// Open a chain for a one-shot batch-request slab: identical to
    /// [`open_stream`](Self::open_stream) except the sliding window (if
    /// the policy has one) is *not* applied — a batched request has a
    /// fixed `seq` and every token must stay visible for the duration of
    /// its batch.  Under a pure LRU policy, retention of its sealed
    /// blocks after the chain closes is governed by capacity pressure as
    /// usual; under a *window* policy [`close_stream`](Self::close_stream)
    /// releases the chain's non-shared blocks at request completion, so
    /// a burst of one-shot requests cannot pin the pool against windowed
    /// streams.
    pub fn open_batch_stream(&mut self) -> StreamChain {
        let mut chain = self.open_stream();
        chain.window = None;
        chain.is_batch = true;
        chain
    }

    /// Append one token's K and V rows (each `token_elems` long) to a
    /// stream: write into the tail block (copy-on-write if the tail is
    /// shared with a fork), seal + dedupe the block when it fills, and
    /// enforce the sliding window.
    pub fn append(&mut self, chain: &mut StreamChain, k_row: &[f32], v_row: &[f32]) {
        self.ensure_writable_tail(chain);
        let tail = chain.tail.as_mut().expect("tail just ensured");
        Arc::get_mut(tail).expect("tail uniquely owned after CoW").push(k_row, v_row);
        chain.appended += 1;
        if tail.is_full() {
            self.seal_tail(chain);
        }
        self.enforce_window(chain);
    }

    /// Bulk-append a whole chunk of tokens — the chunked-prefill ingest
    /// path.  `k`/`v` are `[heads, tokens, head_dim]` row-major slabs
    /// (the server's request/prefill layout; `heads = token_elems /
    /// head_dim`), written in block-sized strides: the tail
    /// allocation/CoW check runs once per stride and sealing, hashing,
    /// prefix lookup, and window enforcement run once per *block*
    /// instead of once per token.
    ///
    /// **Bitwise identical to the per-token loop**: the block bytes,
    /// hash paths, dedupe hits, LRU stamp order, and window drops are
    /// exactly those of calling [`append`](Self::append) with each
    /// token's gathered `[heads, head_dim]` row in order (pinned in
    /// `rust/tests/kv_cache.rs`, including across window-eviction
    /// boundaries).
    ///
    /// # Panics
    ///
    /// Panics if `head_dim` does not divide the cache's `token_elems` or
    /// the slabs are not exactly `tokens * token_elems` long.
    pub fn append_chunk(
        &mut self,
        chain: &mut StreamChain,
        k: &[f32],
        v: &[f32],
        tokens: usize,
        head_dim: usize,
    ) {
        let te = chain.token_elems;
        assert!(
            head_dim > 0 && te % head_dim == 0,
            "head_dim {head_dim} does not divide token_elems {te}"
        );
        assert_eq!(k.len(), tokens * te, "k chunk slab length mismatch");
        assert_eq!(v.len(), tokens * te, "v chunk slab length mismatch");
        let mut t = 0;
        while t < tokens {
            self.ensure_writable_tail(chain);
            let tail_arc = chain.tail.as_mut().expect("tail just ensured");
            let tail = Arc::get_mut(tail_arc).expect("tail uniquely owned after CoW");
            let take = (tail.block_size() - tail.len()).min(tokens - t);
            for i in t..t + take {
                tail.push_strided(k, v, i, tokens, head_dim);
            }
            chain.appended += take;
            t += take;
            if chain.tail.as_ref().is_some_and(|b| b.is_full()) {
                self.seal_tail(chain);
            }
            // window drops are a pure function of the appended count, so
            // enforcing once per stride lands on the same final state as
            // the per-token loop (no seal/lookup happens in between)
            self.enforce_window(chain);
        }
    }

    /// Make the chain's tail block writable: allocate it if absent, and
    /// copy-on-write if a fork still shares it.  Afterwards
    /// `Arc::get_mut(chain.tail)` is guaranteed to succeed.
    fn ensure_writable_tail(&mut self, chain: &mut StreamChain) {
        if chain.tail.is_none() {
            chain.tail = Some(Arc::new(self.pool.alloc()));
        }
        let tail = chain.tail.as_mut().expect("tail just ensured");
        if Arc::get_mut(tail).is_none() {
            // shared with a fork: copy-on-write before diverging
            let copy = Arc::new(self.pool.cow_clone(tail));
            let shared = std::mem::replace(tail, copy);
            self.pool.release(shared);
        }
    }

    /// Seal the (full) tail: dedupe it against the prefix index or insert
    /// it as a new shared block.
    fn seal_tail(&mut self, chain: &mut StreamChain) {
        let tail = chain.tail.take().expect("seal without a tail");
        debug_assert!(tail.is_full());
        let hash = tail.content_hash();
        if let Some(shared) = self.dedupe_sealed(&chain.path, hash, &tail) {
            chain.sealed.push_back(shared);
            // staging storage recycled — except after a spilled-entry
            // promotion, where the index adopted the tail itself and
            // this release just drops one of its clones
            self.pool.release(tail);
            self.hits += 1;
        } else {
            // make room for the newly retained block first — O(log N)
            // heap pops for however many evictions (or demotions, with
            // tiers enabled) the deficit needs
            if self.pool.at_capacity() {
                let over = self.pool.resident() + 1 - self.cfg.capacity_blocks;
                self.relieve_pressure(over);
                // anything still over capacity is referenced by live
                // streams: the cap is exceeded softly
            }
            let entry = CacheEntry::Hot(Arc::clone(&tail));
            if let Some(displaced) = self.index.insert(&chain.path, hash, entry) {
                // hash-collision overwrite (or a stale spilled marker):
                // route the displaced payload through the pool so the
                // residency ledgers stay exact
                self.release_entry(displaced);
                self.evictions += 1;
            }
            chain.sealed.push_back(SealedRef::Hot(tail));
            self.allocs += 1;
        }
        chain.path.push(hash);
    }

    /// The tier-aware half of a seal: resolve `path` + `hash` against the
    /// index and verify the stored representation against the freshly
    /// sealed `candidate`.  Hot entries verify bitwise; quantised entries
    /// verify by re-encoding the candidate ([`QuantBlock::matches_quantised`]);
    /// spilled entries re-read + re-verify the archived bytes and, on an
    /// exact match, promote the node to hot by *adopting the candidate's
    /// own block* (zero-copy — the disk read only confirms the bytes).
    /// Any mismatch or spill corruption returns `None` — a clean miss.
    ///
    /// With tiers off this is exactly the old fused lookup: one clock
    /// bump per seal (hit or miss), stamp-on-hit — the stamp sequence,
    /// and therefore eviction order, is bitwise unchanged.
    fn dedupe_sealed(
        &mut self,
        path: &[u64],
        hash: u64,
        candidate: &Arc<KvBlock>,
    ) -> Option<SealedRef> {
        let id = self.index.probe(path, hash)?;
        match self.index.entry_cloned(id)? {
            CacheEntry::Hot(block) => {
                if !block.content_eq(candidate) {
                    return None; // hash collision: never share
                }
                self.index.touch_probed(id);
                Some(SealedRef::Hot(block))
            }
            CacheEntry::Quant(q) => {
                if !q.matches_quantised(candidate) {
                    return None;
                }
                self.index.touch_probed(id);
                Some(SealedRef::Quant(q))
            }
            CacheEntry::Spilled => {
                let store = self.store.as_ref()?;
                match store.read(hash, self.pool.token_elems(), self.cfg.block_size) {
                    Ok(block) if block.content_eq(candidate) => {
                        self.spill_hits += 1;
                        let old = self
                            .index
                            .replace_entry(id, CacheEntry::Hot(Arc::clone(candidate)));
                        debug_assert!(matches!(old, Some(CacheEntry::Spilled)));
                        self.index.touch_probed(id);
                        Some(SealedRef::Hot(Arc::clone(candidate)))
                    }
                    Ok(_) => None, // hash collision with archived content
                    Err(_) => {
                        // truncated, flipped, or missing file: degrade to
                        // a miss and drop the bad file so the next
                        // demotion re-archives clean bytes
                        self.spill_corrupt += 1;
                        store.remove(hash);
                        None
                    }
                }
            }
        }
    }

    /// Bring resident hot blocks back under capacity by `need` blocks.
    /// With tiers disabled this is plain LRU eviction (bitwise identical
    /// to the pre-tier cache); with any rung enabled, victims are handed
    /// to the [`TierLadder`] instead: hot blocks archive their exact
    /// bytes to the spill store (write-once, at first demotion) and
    /// re-encode one rung colder (f16/int8), already-quantised blocks
    /// sink further, and a block below the last enabled rung falls to
    /// the disk-only spilled marker (if archived) or is dropped.  Each
    /// pressure pass sinks a given block at most one rung.
    fn relieve_pressure(&mut self, need: usize) {
        if !self.cfg.tiers.enabled() {
            for entry in self.index.evict_lru_batch(need) {
                self.release_entry(entry);
                self.evictions += 1;
            }
            return;
        }
        let Self { cfg, pool, index, store, .. } = self;
        let (mut demoted, mut spilled, mut evicted, mut write_errors) = (0u64, 0u64, 0u64, 0u64);
        index.demote_lru_batch(need, |path, entry| {
            let hash = *path.last().expect("demoted nodes carry their hash");
            let ancestors = &path[..path.len() - 1];
            match entry {
                CacheEntry::Hot(block) => {
                    // archive the exact bytes now, while they still exist
                    // in RAM — later rungs only ever check `contains`
                    let archived = match store {
                        Some(s) => match s.write(ancestors, hash, &block) {
                            Ok(_) => true,
                            Err(_) => {
                                write_errors += 1;
                                false
                            }
                        },
                        None => false,
                    };
                    match cfg.tiers.next_quant(BlockTier::F32) {
                        Some(t) => {
                            let q = QuantBlock::quantise(&block, t);
                            pool.note_quant(q.payload_bytes());
                            pool.release(block);
                            demoted += 1;
                            Some(CacheEntry::Quant(Arc::new(q)))
                        }
                        None if archived => {
                            pool.release(block);
                            spilled += 1;
                            Some(CacheEntry::Spilled)
                        }
                        None => {
                            pool.release(block);
                            evicted += 1;
                            None
                        }
                    }
                }
                CacheEntry::Quant(q) => {
                    if let Some(t) = cfg.tiers.next_quant(q.tier()) {
                        let colder = QuantBlock::requantise(&q, t);
                        pool.note_quant(colder.payload_bytes());
                        pool.release_quant(q);
                        demoted += 1;
                        Some(CacheEntry::Quant(Arc::new(colder)))
                    } else if store.as_ref().is_some_and(|s| s.contains(hash)) {
                        pool.release_quant(q);
                        spilled += 1;
                        Some(CacheEntry::Spilled)
                    } else {
                        // never archived (no spill dir, or its write
                        // failed): the ladder ends here
                        pool.release_quant(q);
                        evicted += 1;
                        None
                    }
                }
                CacheEntry::Spilled => {
                    unreachable!("demote_lru_batch never yields spilled entries")
                }
            }
        });
        self.demotions += demoted;
        self.spills += spilled;
        self.evictions += evicted;
        self.spill_write_errors += write_errors;
    }

    /// Release a cache entry's payload through the pool ledgers (spilled
    /// entries hold none).
    fn release_entry(&mut self, entry: CacheEntry) {
        match entry {
            CacheEntry::Hot(b) => self.pool.release(b),
            CacheEntry::Quant(q) => self.pool.release_quant(q),
            CacheEntry::Spilled => {}
        }
    }

    /// Release a chain's reference to one of its sealed blocks.
    fn release_sealed(&mut self, sealed: SealedRef) {
        match sealed {
            SealedRef::Hot(b) => self.pool.release(b),
            SealedRef::Quant(q) => self.pool.release_quant(q),
        }
    }

    /// Release sealed front blocks that fell fully outside the window.
    /// With no capacity bound configured there is no later LRU pass to
    /// reclaim index retention, so the index's clone is dropped eagerly
    /// too (unless another stream still shares the block) — a windowed
    /// stream's resident footprint stays O(window), not O(total tokens).
    fn enforce_window(&mut self, chain: &mut StreamChain) {
        let Some(w) = chain.window else {
            return;
        };
        let first_needed_block = chain.appended.saturating_sub(w) / chain.block_size;
        while chain.dropped_blocks < first_needed_block {
            let Some(front) = chain.sealed.pop_front() else {
                break;
            };
            if self.cfg.capacity_blocks == 0 {
                let path = &chain.path[..chain.dropped_blocks];
                let hash = chain.path[chain.dropped_blocks];
                if let Some(evicted) = self.index.remove_if_unshared(path, hash, &front) {
                    self.release_entry(evicted);
                    self.evictions += 1;
                }
            }
            self.release_sealed(front);
            chain.dropped_blocks += 1;
        }
    }

    /// Close a stream, releasing its blocks.  Sealed blocks the prefix
    /// index retains stay resident (a resubmitted prompt still hits) until
    /// capacity pressure evicts them — except for a *batch* chain under a
    /// *window* policy: batch chains are window-exempt while open and a
    /// window policy may have no capacity bound (so no later LRU pass),
    /// which would let a burst of one-shot batch requests pin the pool
    /// indefinitely.  For that combination the chain's sealed blocks that
    /// no other live stream shares are removed from the index and
    /// released here, at request completion (counted in
    /// [`KvCacheStats::evicted_blocks`]); blocks a live stream still
    /// shares are kept.
    pub fn close_stream(&mut self, chain: StreamChain) {
        if chain.is_batch && self.cfg.window().is_some() {
            // batch chains never drop front blocks (window-exempt), so
            // sealed[i]'s trie position is exactly path[..i] + path[i]
            debug_assert_eq!(chain.dropped_blocks, 0);
            for (i, block) in chain.sealed.iter().enumerate() {
                if let Some(evicted) =
                    self.index.remove_if_unshared(&chain.path[..i], chain.path[i], block)
                {
                    self.release_entry(evicted);
                    self.evictions += 1;
                }
            }
        }
        for block in chain.sealed {
            self.release_sealed(block);
        }
        if let Some(tail) = chain.tail {
            self.pool.release(tail);
        }
    }

    /// Aggregate counters (monotonic except `resident_blocks` and
    /// `quant_blocks`).
    ///
    /// The serve loop snapshots these into its stats reply and — when
    /// telemetry is on — mirrors residency into the
    /// `skein_kv_resident_blocks` / `skein_kv_resident_bytes` gauges
    /// and classifies per-request ingest spans as
    /// [`KvIngestHit`](crate::obs::Span::KvIngestHit) vs
    /// [`KvIngestMiss`](crate::obs::Span::KvIngestMiss) from the
    /// `hit_blocks` / `alloc_blocks` deltas around each ingest.
    pub fn stats(&self) -> KvCacheStats {
        KvCacheStats {
            hit_blocks: self.hits,
            alloc_blocks: self.allocs,
            evicted_blocks: self.evictions,
            resident_blocks: self.pool.resident() as u64,
            quant_blocks: self.pool.quant_resident() as u64,
            demoted_blocks: self.demotions,
            spilled_blocks: self.spills,
            spill_hits: self.spill_hits,
            spill_corrupt: self.spill_corrupt,
        }
    }

    /// Snapshot the index to the spill store: every index-only entry
    /// (nothing outside the index referencing it) archives its exact
    /// bytes — hot blocks write them now; quantised blocks only qualify
    /// if their first demotion already did — and is swapped for a
    /// disk-only spilled marker, releasing its RAM.  Entries live
    /// streams still reference, and quantised blocks that were never
    /// archived, stay put.  Returns how many entries were spilled.
    ///
    /// This is the warm-restart/handoff hook: after `spill_index`, a
    /// fresh cache opened over the same directory (see
    /// [`new`](Self::new)) replays previously cached prefixes without
    /// fresh block allocations, and a concurrently serving process sees
    /// the same archive.  A no-op without a spill store.
    pub fn spill_index(&mut self) -> usize {
        let Self { pool, index, store, .. } = self;
        let Some(store) = store.as_ref() else {
            return 0;
        };
        let mut written = 0usize;
        let mut write_errors = 0u64;
        index.for_each_entry_mut(|path, slot| {
            let hash = *path.last().expect("entry nodes carry their hash");
            let ancestors = &path[..path.len() - 1];
            match slot.take().expect("visited nodes hold entries") {
                CacheEntry::Hot(block) => {
                    if Arc::strong_count(&block) == 1 {
                        match store.write(ancestors, hash, &block) {
                            Ok(_) => {
                                pool.release(block);
                                *slot = Some(CacheEntry::Spilled);
                                written += 1;
                            }
                            Err(_) => {
                                write_errors += 1;
                                *slot = Some(CacheEntry::Hot(block));
                            }
                        }
                    } else {
                        *slot = Some(CacheEntry::Hot(block));
                    }
                }
                CacheEntry::Quant(q) => {
                    if Arc::strong_count(&q) == 1 && store.contains(hash) {
                        pool.release_quant(q);
                        *slot = Some(CacheEntry::Spilled);
                        written += 1;
                    } else {
                        *slot = Some(CacheEntry::Quant(q));
                    }
                }
                CacheEntry::Spilled => *slot = Some(CacheEntry::Spilled),
            }
        });
        self.spills += written as u64;
        self.spill_write_errors += write_errors;
        written
    }

    /// The spill tier's on-disk store, when one is configured and open
    /// (test + tooling access; the fault-injection suite corrupts block
    /// files through [`BlockStore::block_path`]).
    pub fn spill_store(&self) -> Option<&BlockStore> {
        self.store.as_ref()
    }

    /// Lifetime block allocations that touched the heap (the pool's free
    /// list was empty) — see [`BlockPool::fresh_allocs`].  A replayed
    /// prompt or resubmitted batch slab leaves this flat.
    pub fn fresh_allocs(&self) -> u64 {
        self.pool.fresh_allocs()
    }

    /// Resident KV bytes: hot blocks × block_size × token_elems ×
    /// (K + V) × 4, plus the quantised blocks' payload bytes.  Spilled
    /// entries contribute nothing — their bytes live on disk.
    pub fn resident_kv_bytes(&self) -> u64 {
        self.pool.resident() as u64
            * self.cfg.block_size as u64
            * self.pool.token_elems() as u64
            * 2
            * std::mem::size_of::<f32>() as u64
            + self.pool.quant_bytes() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(cache: &mut KvCache, chain: &mut StreamChain, tokens: std::ops::Range<usize>) {
        for t in tokens {
            let row = vec![t as f32, -(t as f32)];
            cache.append(chain, &row, &row);
        }
    }

    fn cache(block_size: usize) -> KvCache {
        KvCache::new(KvCacheConfig::new(block_size), 2)
    }

    #[test]
    fn shared_prefix_allocates_once() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..6);
        assert_eq!(c.stats().alloc_blocks, 3);
        assert_eq!(c.stats().hit_blocks, 0);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 0..6);
        let s = c.stats();
        assert_eq!(s.alloc_blocks, 3, "replayed prefix must not allocate");
        assert_eq!(s.hit_blocks, 3);
        // diverging suffix allocates again
        fill(&mut c, &mut b, 10..12);
        assert_eq!(c.stats().alloc_blocks, 4);
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn diverging_streams_do_not_share() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        let mut b = c.open_stream();
        fill(&mut c, &mut a, 0..2);
        fill(&mut c, &mut b, 5..7);
        // same second block contents, but different prefix path: no share
        fill(&mut c, &mut a, 100..102);
        fill(&mut c, &mut b, 100..102);
        assert_eq!(c.stats().hit_blocks, 0);
        assert_eq!(c.stats().alloc_blocks, 4);
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn gather_reproduces_append_order() {
        let mut c = cache(3);
        let mut chain = c.open_stream();
        fill(&mut c, &mut chain, 0..7); // 2 sealed blocks + 1 tail token
        assert_eq!(chain.visible_len(), 7);
        let mut k = Matrix::zeros(7, 1);
        let mut v = Matrix::zeros(7, 1);
        // head 1 of head_dim 1: the second element of each token row
        chain.gather_head_into(1, 1, &mut k, &mut v);
        for t in 0..7 {
            assert_eq!(k.get(t, 0), -(t as f32), "token {t}");
        }
        c.close_stream(chain);
    }

    #[test]
    fn fork_is_copy_on_write() {
        let mut c = cache(4);
        let mut parent = c.open_stream();
        fill(&mut c, &mut parent, 0..6); // 1 sealed + 2 tail tokens
        let resident_before = c.stats().resident_blocks;
        let mut child = parent.fork();
        assert_eq!(c.stats().resident_blocks, resident_before, "fork allocates nothing");
        // diverge the child; the parent's tail must be unaffected
        c.append(&mut child, &[99.0, 99.0], &[99.0, 99.0]);
        let mut pk = Matrix::zeros(6, 2);
        let mut pv = Matrix::zeros(6, 2);
        parent.gather_head_into(0, 2, &mut pk, &mut pv);
        assert_eq!(pk.get(5, 0), 5.0, "parent tail unchanged after child append");
        let mut ck = Matrix::zeros(7, 2);
        let mut cv = Matrix::zeros(7, 2);
        child.gather_head_into(0, 2, &mut ck, &mut cv);
        assert_eq!(ck.get(6, 0), 99.0);
        assert_eq!(ck.get(5, 0), 5.0, "shared prefix preserved in the fork");
        c.close_stream(parent);
        c.close_stream(child);
        assert_eq!(c.stats().resident_blocks, 1, "only the sealed (indexed) block remains");
    }

    #[test]
    fn sliding_window_releases_front_blocks() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 2);
        let mut chain = c.open_stream();
        fill(&mut c, &mut chain, 0..10);
        assert_eq!(chain.appended(), 10);
        assert_eq!(chain.visible_len(), 4);
        // tokens 0..6 are outside the window: blocks 0-2 dropped
        assert_eq!(chain.block_count(), 2);
        // no capacity bound configured, so index retention of the
        // dropped (unshared) blocks is released eagerly: resident stays
        // O(window), not O(appended)
        assert_eq!(c.stats().evicted_blocks, 3);
        assert_eq!(c.stats().resident_blocks, 2);
        let mut k = Matrix::zeros(4, 2);
        let mut v = Matrix::zeros(4, 2);
        chain.gather_head_into(0, 2, &mut k, &mut v);
        for (i, t) in (6..10).enumerate() {
            assert_eq!(k.get(i, 0), t as f32, "window row {i}");
        }
        c.close_stream(chain);
    }

    #[test]
    fn window_drop_keeps_blocks_another_stream_shares() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 2);
        let mut a = c.open_stream();
        let mut b = c.open_stream();
        fill(&mut c, &mut a, 0..4); // 2 sealed, all inside the window
        fill(&mut c, &mut b, 0..4); // shares both
        // stream a outgrows the window; its front block is still shared
        // with b, so the index keeps it and b stays fully readable
        fill(&mut c, &mut a, 4..8);
        let mut k = Matrix::zeros(4, 2);
        let mut v = Matrix::zeros(4, 2);
        b.gather_head_into(0, 2, &mut k, &mut v);
        for t in 0..4 {
            assert_eq!(k.get(t, 0), t as f32, "shared block must survive a's window");
        }
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn capacity_evicts_only_unreferenced_blocks() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_capacity_blocks(3), 2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..6); // 3 sealed blocks: at capacity
        // a new stream needs blocks; everything is referenced by `a`, so
        // nothing is evicted and the cap is exceeded softly
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 50..52);
        assert_eq!(c.stats().evicted_blocks, 0);
        assert!(c.stats().resident_blocks > 3);
        c.close_stream(a);
        // now a's blocks are index-only; further sealing evicts LRU ones
        fill(&mut c, &mut b, 52..56);
        assert!(c.stats().evicted_blocks > 0);
        c.close_stream(b);
    }

    /// Build `[heads, tokens, head_dim]` chunk slabs whose token rows
    /// are `fill(t)` — the gathered per-token row of token `t`.
    fn chunk_slabs(
        range: std::ops::Range<usize>,
        heads: usize,
        head_dim: usize,
        fill: impl Fn(usize) -> Vec<f32>,
    ) -> (Vec<f32>, Vec<f32>) {
        let tokens = range.len();
        let mut k = vec![0.0f32; tokens * heads * head_dim];
        for (i, t) in range.enumerate() {
            let row = fill(t);
            for h in 0..heads {
                let dst = h * tokens * head_dim + i * head_dim;
                k[dst..dst + head_dim].copy_from_slice(&row[h * head_dim..(h + 1) * head_dim]);
            }
        }
        (k.clone(), k)
    }

    #[test]
    fn append_chunk_is_bitwise_identical_to_per_token_appends() {
        // 13 tokens through chunks {4, 6, 3} vs one-at-a-time, sliding
        // window 5 at block size 2: strides cross both block seals and
        // window-eviction boundaries
        let row = |t: usize| vec![t as f32, -(t as f32)];
        let mut per_tok = KvCache::new(KvCacheConfig::new(2).with_window(5), 2);
        let mut chunked = KvCache::new(KvCacheConfig::new(2).with_window(5), 2);
        let mut a = per_tok.open_stream();
        let mut b = chunked.open_stream();
        for t in 0..13 {
            let r = row(t);
            per_tok.append(&mut a, &r, &r);
        }
        for range in [0..4, 4..10, 10..13] {
            // heads = 2, head_dim = 1 (token_elems = 2)
            let (k, v) = chunk_slabs(range.clone(), 2, 1, row);
            chunked.append_chunk(&mut b, &k, &v, range.len(), 1);
        }
        assert_eq!(a.appended(), b.appended());
        assert_eq!(a.visible_len(), b.visible_len());
        assert_eq!(a.block_count(), b.block_count());
        let gather = |chain: &StreamChain| {
            let n = chain.visible_len();
            let mut k = Matrix::zeros(n, 2);
            let mut v = Matrix::zeros(n, 2);
            chain.gather_head_into(0, 2, &mut k, &mut v);
            (k, v)
        };
        let (ka, va) = gather(&a);
        let (kb, vb) = gather(&b);
        assert_eq!(ka.max_abs_diff(&kb), 0.0, "chunked K diverged from per-token");
        assert_eq!(va.max_abs_diff(&vb), 0.0, "chunked V diverged from per-token");
        let (sa, sb) = (per_tok.stats(), chunked.stats());
        assert_eq!(sa.alloc_blocks, sb.alloc_blocks);
        assert_eq!(sa.hit_blocks, sb.hit_blocks);
        assert_eq!(sa.evicted_blocks, sb.evicted_blocks);
        assert_eq!(sa.resident_blocks, sb.resident_blocks);
        per_tok.close_stream(a);
        chunked.close_stream(b);
    }

    #[test]
    fn append_chunk_dedupes_against_per_token_ingest() {
        // a chunked replay of a per-token-ingested prompt must hit every
        // sealed block — the two granularities share one hash path
        let row = |t: usize| vec![t as f32, t as f32 + 0.5];
        let mut c = cache(2);
        let mut a = c.open_stream();
        for t in 0..6 {
            let r = row(t);
            c.append(&mut a, &r, &r);
        }
        assert_eq!(c.stats().alloc_blocks, 3);
        let mut b = c.open_stream();
        let (k, v) = chunk_slabs(0..6, 1, 2, row);
        c.append_chunk(&mut b, &k, &v, 6, 2);
        let s = c.stats();
        assert_eq!(s.alloc_blocks, 3, "chunked replay must not allocate");
        assert_eq!(s.hit_blocks, 3, "chunked replay shares every sealed block");
        c.close_stream(a);
        c.close_stream(b);
    }

    #[test]
    fn batch_stream_ignores_the_window() {
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4), 1);
        let mut chain = c.open_batch_stream();
        for t in 0..10 {
            c.append(&mut chain, &[t as f32], &[t as f32]);
        }
        assert_eq!(chain.visible_len(), 10, "batch chains keep the full request");
        assert_eq!(c.stats().evicted_blocks, 0);
        c.close_stream(chain);
    }

    #[test]
    fn batch_chain_close_returns_residency_to_baseline_under_a_window() {
        // --kv-batch-dedupe + --kv-window: batch chains are window-exempt
        // while open, and the window policy has no capacity bound, so
        // without release-at-completion a burst of one-shot requests
        // would pin the pool indefinitely
        let mut c = KvCache::new(KvCacheConfig::new(2).with_window(4).with_batch_dedupe(true), 1);
        let baseline = c.stats().resident_blocks;
        for burst in 0..5 {
            let mut chain = c.open_batch_stream();
            for t in 0..8 {
                let x = (burst * 8 + t) as f32; // distinct content per request
                c.append(&mut chain, &[x], &[x]);
            }
            assert_eq!(chain.visible_len(), 8, "batch chains stay window-exempt");
            c.close_stream(chain);
        }
        assert_eq!(
            c.stats().resident_blocks,
            baseline,
            "batch burst must not pin the pool"
        );
        assert_eq!(c.stats().evicted_blocks, 20, "4 sealed blocks released per request");

        // a block shared with a live stream survives the batch close
        let mut live = c.open_stream();
        for t in 0..2 {
            c.append(&mut live, &[t as f32], &[t as f32]);
        }
        let mut batch = c.open_batch_stream();
        for t in 0..2 {
            c.append(&mut batch, &[t as f32], &[t as f32]);
        }
        assert_eq!(c.stats().hit_blocks, 1, "batch chain shares the live stream's block");
        c.close_stream(batch);
        let mut k = Matrix::zeros(2, 1);
        let mut v = Matrix::zeros(2, 1);
        live.gather_head_into(0, 1, &mut k, &mut v);
        assert_eq!(k.get(0, 0), 0.0, "shared block must survive the batch close");
        assert_eq!(k.get(1, 0), 1.0);
        c.close_stream(live);
    }

    #[test]
    fn pressure_demotes_to_f16_and_replay_hits_quant() {
        let tiers = TierLadder::none().with_f16(true);
        let mut c =
            KvCache::new(KvCacheConfig::new(2).with_capacity_blocks(2).with_tiers(tiers), 2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..4); // 2 sealed blocks: exactly at capacity
        c.close_stream(a); // index-only now: demotable
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 50..52); // one sealing miss forces pressure
        let s = c.stats();
        assert_eq!(s.demoted_blocks, 2, "pressure must demote, not drop");
        assert_eq!(s.evicted_blocks, 0, "the f16 rung absorbs the pressure");
        assert_eq!(s.quant_blocks, 2);
        assert!(c.resident_kv_bytes() > 0);
        c.close_stream(b);
        // replaying the demoted prompt dedupes against the quantised
        // entries (verified by re-encoding) and gathers decode in place
        let mut r = c.open_stream();
        fill(&mut c, &mut r, 0..4);
        assert_eq!(c.stats().hit_blocks, 2, "quantised entries still dedupe");
        assert_eq!(c.stats().demoted_blocks, 2, "hits never demote further");
        let mut k = Matrix::zeros(4, 2);
        let mut v = Matrix::zeros(4, 2);
        r.gather_head_into(0, 2, &mut k, &mut v);
        for t in 0..4 {
            // small integers are f16-exact, so the decode is lossless here
            assert_eq!(k.get(t, 0), t as f32, "f16-exact value must round trip");
            assert_eq!(k.get(t, 1), -(t as f32));
        }
        c.close_stream(r);
    }

    #[test]
    fn spill_only_ladder_archives_and_rehydrates_bitwise() {
        let dir = tempdir("mod-spill");
        let tiers = TierLadder::none().with_spill_dir(dir.path());
        let mut c =
            KvCache::new(KvCacheConfig::new(2).with_capacity_blocks(1).with_tiers(tiers), 2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..2); // 1 sealed block: at capacity
        c.close_stream(a);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 50..52); // pressure: a's block archives + spills
        let s = c.stats();
        assert_eq!(s.spilled_blocks, 1, "no quant rung: hot spills directly");
        assert_eq!(s.evicted_blocks, 0);
        assert_eq!(s.resident_blocks, 1, "spilled entry holds no RAM");
        c.close_stream(b);
        // replaying the spilled prompt re-reads + re-verifies the archive
        // and promotes the entry back to hot, adopting the new tail
        let mut r = c.open_stream();
        fill(&mut c, &mut r, 0..2);
        let s = c.stats();
        assert_eq!(s.spill_hits, 1, "replay rehydrates from the archive");
        assert_eq!(s.hit_blocks, 1);
        assert_eq!(s.spill_corrupt, 0);
        let mut k = Matrix::zeros(2, 2);
        let mut v = Matrix::zeros(2, 2);
        r.gather_head_into(0, 2, &mut k, &mut v);
        for t in 0..2 {
            assert_eq!(k.get(t, 0), t as f32, "rehydrated bytes must be exact");
        }
        c.close_stream(r);
    }

    #[test]
    fn closed_stream_prefix_still_hits() {
        let mut c = cache(2);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..4);
        c.close_stream(a);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 0..4);
        assert_eq!(c.stats().hit_blocks, 2, "resubmitted prompt hits after close");
        c.close_stream(b);
    }
}
