//! The block allocator: hands out [`KvBlock`]s, recycles their storage,
//! and tracks residency against a configurable capacity.
//!
//! All block lifetimes flow through the pool: [`BlockPool::alloc`] hands
//! out a block (recycled storage when available, so steady-state serving
//! stops allocating), and every `Arc<KvBlock>` a chain or the prefix
//! index lets go of comes back through [`BlockPool::release`] — when the
//! released clone is the *last* reference the storage returns to the free
//! list and the resident count drops.  Dropping an `Arc` without telling
//! the pool is safe (the memory is freed) but leaks the residency
//! accounting, so the cache layer never does it.
//!
//! The pool does not decide *what* to evict — that is the
//! [`PrefixIndex`](super::PrefixIndex) + policy's job — it only answers
//! [`at_capacity`](BlockPool::at_capacity), which the cache consults
//! before allocating.  Capacity is a bound on cache *retention*, not on
//! live streams: a stream that legitimately needs one more block always
//! gets it, and eviction of unreferenced index entries brings the count
//! back down.

use super::block::KvBlock;
use super::tier::QuantBlock;
use std::sync::Arc;

/// How many freed (K, V) storage pairs the pool keeps for reuse.
const FREE_KEEP: usize = 64;

/// Allocator + residency accounting for fixed-size KV blocks.
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    token_elems: usize,
    /// Max resident blocks; 0 = unbounded.
    capacity: usize,
    /// Recycled (K, V) storage pairs.
    free: Vec<(Vec<f32>, Vec<f32>)>,
    /// Blocks currently handed out and not yet reclaimed.
    resident: usize,
    /// Lifetime allocations (monotonic, for stats).
    total_allocs: u64,
    /// Lifetime allocations that had to touch the heap (no recycled
    /// storage available) — steady-state serving keeps this flat.
    fresh_allocs: u64,
    /// Quantised (f16/int8) blocks currently alive.  Tracked separately
    /// from `resident`: [`at_capacity`](Self::at_capacity) bounds *hot*
    /// blocks only, so the tiers-off pressure behaviour is untouched and
    /// demoting a hot block relieves pressure exactly like evicting it.
    quant_resident: usize,
    /// Payload bytes of the live quantised blocks (for the resident-KV
    /// footprint stat).
    quant_bytes: usize,
}

impl BlockPool {
    /// A pool of `block_size`-token blocks at `token_elems` f32s per token
    /// row.  `capacity` bounds resident blocks (0 = unbounded).
    pub fn new(block_size: usize, token_elems: usize, capacity: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        assert!(token_elems > 0, "token_elems must be positive");
        Self {
            block_size,
            token_elems,
            capacity,
            free: Vec::new(),
            resident: 0,
            total_allocs: 0,
            fresh_allocs: 0,
            quant_resident: 0,
            quant_bytes: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn token_elems(&self) -> usize {
        self.token_elems
    }

    /// Blocks currently alive (streams + prefix index).
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Lifetime [`alloc`](Self::alloc) count.
    pub fn total_allocs(&self) -> u64 {
        self.total_allocs
    }

    /// Lifetime allocs that touched the heap (the free list was empty).
    /// A replayed prompt or resubmitted batch slab leaves this flat —
    /// its working blocks come back recycled.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh_allocs
    }

    /// True when the resident count has reached the configured capacity —
    /// the cache should evict unreferenced index entries before (or right
    /// after) the next alloc.
    pub fn at_capacity(&self) -> bool {
        self.capacity > 0 && self.resident >= self.capacity
    }

    /// Hand out an empty block, reusing freed storage when available.
    pub fn alloc(&mut self) -> KvBlock {
        let elems = self.block_size * self.token_elems;
        let (mut k, mut v) = match self.free.pop() {
            Some(pair) => pair,
            None => {
                self.fresh_allocs += 1;
                Default::default()
            }
        };
        k.clear();
        k.resize(elems, 0.0);
        v.clear();
        v.resize(elems, 0.0);
        self.resident += 1;
        self.total_allocs += 1;
        KvBlock::from_storage(k, v, self.token_elems)
    }

    /// A copy-on-write duplicate of `block` — a fresh block with the same
    /// filled contents, counted as a new allocation (the fork path uses
    /// this when a shared tail must diverge).
    pub fn cow_clone(&mut self, block: &KvBlock) -> KvBlock {
        let mut fresh = self.alloc();
        for slot in 0..block.len() {
            fresh.push(block.k_token(slot), block.v_token(slot));
        }
        fresh
    }

    /// Release one `Arc` clone of a block.  If it was the last reference
    /// the block's storage returns to the free list and the resident
    /// count drops; otherwise the block stays alive for its remaining
    /// holders and only this clone goes away.
    pub fn release(&mut self, block: Arc<KvBlock>) {
        if let Ok(owned) = Arc::try_unwrap(block) {
            self.resident = self.resident.saturating_sub(1);
            if self.free.len() < FREE_KEEP {
                self.free.push(owned.into_storage());
            }
        }
    }

    /// Quantised blocks currently alive (index + chains).
    pub fn quant_resident(&self) -> usize {
        self.quant_resident
    }

    /// Payload bytes held by live quantised blocks.
    pub fn quant_bytes(&self) -> usize {
        self.quant_bytes
    }

    /// Record a freshly created quantised block (`bytes` =
    /// [`QuantBlock::payload_bytes`]).  Quantised storage is plain heap
    /// memory — no free-list recycling, no capacity pressure — so the
    /// ledger only tracks counts and bytes.
    pub fn note_quant(&mut self, bytes: usize) {
        self.quant_resident += 1;
        self.quant_bytes += bytes;
    }

    /// Release one `Arc` clone of a quantised block; the ledger drops
    /// when this was the last reference (mirror of
    /// [`release`](Self::release)).
    pub fn release_quant(&mut self, block: Arc<QuantBlock>) {
        if let Ok(owned) = Arc::try_unwrap(block) {
            self.quant_resident = self.quant_resident.saturating_sub(1);
            self.quant_bytes = self.quant_bytes.saturating_sub(owned.payload_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_storage() {
        let mut pool = BlockPool::new(4, 2, 0);
        let mut b = pool.alloc();
        assert_eq!(pool.resident(), 1);
        b.push(&[1.0, 2.0], &[3.0, 4.0]);
        let ptr = b.k_token(0).as_ptr();
        pool.release(Arc::new(b));
        assert_eq!(pool.resident(), 0);
        let again = pool.alloc();
        assert_eq!(pool.resident(), 1);
        assert!(again.is_empty(), "recycled block must come back empty");
        // same backing allocation, reused
        let mut again = again;
        again.push(&[0.0, 0.0], &[0.0, 0.0]);
        assert_eq!(again.k_token(0).as_ptr(), ptr);
        assert_eq!(pool.total_allocs(), 2);
        assert_eq!(pool.fresh_allocs(), 1, "second alloc must reuse recycled storage");
    }

    #[test]
    fn shared_blocks_survive_partial_release() {
        let mut pool = BlockPool::new(2, 1, 0);
        let block = Arc::new(pool.alloc());
        let clone = Arc::clone(&block);
        pool.release(clone); // one of two refs: block stays resident
        assert_eq!(pool.resident(), 1);
        pool.release(block); // last ref: reclaimed
        assert_eq!(pool.resident(), 0);
    }

    #[test]
    fn capacity_reports_but_never_blocks_allocation() {
        let mut pool = BlockPool::new(2, 1, 2);
        let a = Arc::new(pool.alloc());
        assert!(!pool.at_capacity());
        let b = Arc::new(pool.alloc());
        assert!(pool.at_capacity());
        let c = Arc::new(pool.alloc()); // soft cap: live streams always get a block
        assert_eq!(pool.resident(), 3);
        pool.release(a);
        pool.release(b);
        assert!(!pool.at_capacity());
        pool.release(c);
    }

    #[test]
    fn cow_clone_copies_contents() {
        let mut pool = BlockPool::new(3, 2, 0);
        let mut orig = pool.alloc();
        orig.push(&[1.0, 2.0], &[3.0, 4.0]);
        let copy = pool.cow_clone(&orig);
        assert_eq!(copy.len(), 1);
        assert_eq!(copy.k_token(0), orig.k_token(0));
        assert_eq!(copy.v_token(0), orig.v_token(0));
        assert!(copy.content_eq(&orig));
        assert_eq!(pool.resident(), 2);
    }
}
