//! The unit of paged KV storage: a fixed-capacity block of streamed
//! tokens.
//!
//! A [`KvBlock`] holds up to `block_size` tokens, each token one K row and
//! one V row of `token_elems` f32s (the stream's `[heads, head_dim]`
//! slab, heads contiguous).  Blocks are handed out by
//! [`BlockPool`](super::BlockPool) and shared between streams as
//! `Arc<KvBlock>`:
//!
//! * a **sealed** block (full) is immutable — once its content hash is
//!   registered in the [`PrefixIndex`](super::PrefixIndex) the bytes never
//!   change, so any number of streams may hold clones of the `Arc`;
//! * the **tail** block of a stream (partially filled) is mutable only
//!   while uniquely owned — a forked stream that shares a tail must
//!   copy-on-write before appending (`Arc::get_mut` fails, the chain
//!   clones; see [`StreamChain`](super::StreamChain)).
//!
//! Content hashing is [FNV-1a] over the filled K then V bit patterns — a
//! pure function of the token contents, so two streams that append the
//! same tokens produce the same hash sequence and land on the same trie
//! path.  Hash hits are always verified by full content comparison
//! ([`KvBlock::content_eq`]) before a block is shared: a collision
//! degrades to a cache miss, never to wrong bytes.
//!
//! [FNV-1a]: https://en.wikipedia.org/wiki/Fowler%E2%80%93Noll%E2%80%93Vo_hash_function

/// One fixed-capacity block of streamed tokens (see the [module
/// docs](self) for the sharing/mutability contract).
#[derive(Clone, Debug)]
pub struct KvBlock {
    /// `block_size * token_elems` backing storage (fully allocated up
    /// front so recycled blocks never reallocate); only the first
    /// `len * token_elems` elements are meaningful.
    k: Vec<f32>,
    v: Vec<f32>,
    token_elems: usize,
    len: usize,
}

impl KvBlock {
    /// Wrap (recycled or fresh) backing storage as an empty block.
    /// `k`/`v` must each hold exactly `block_size * token_elems` elements.
    pub(super) fn from_storage(k: Vec<f32>, v: Vec<f32>, token_elems: usize) -> Self {
        assert_eq!(k.len(), v.len(), "K/V storage sizes differ");
        assert!(token_elems > 0, "token_elems must be positive");
        assert_eq!(k.len() % token_elems, 0, "storage not a whole number of tokens");
        Self { k, v, token_elems, len: 0 }
    }

    /// Reclaim the backing storage (pool recycling).
    pub(super) fn into_storage(self) -> (Vec<f32>, Vec<f32>) {
        (self.k, self.v)
    }

    /// Rebuild a block from already-filled K/V payloads (exactly
    /// `len * token_elems` elements each) — the spill-store rehydration
    /// path.  The result is outside pool accounting: it exists only to
    /// be verified against a candidate and dropped.
    pub(super) fn from_filled(k: Vec<f32>, v: Vec<f32>, token_elems: usize, len: usize) -> Self {
        assert!(token_elems > 0, "token_elems must be positive");
        assert_eq!(k.len(), len * token_elems, "K payload is not len tokens");
        assert_eq!(v.len(), len * token_elems, "V payload is not len tokens");
        Self { k, v, token_elems, len }
    }

    /// The filled K payload (`len * token_elems` elements, token rows
    /// contiguous) — what the tier codecs encode and the spill store
    /// archives.
    pub fn k_filled(&self) -> &[f32] {
        &self.k[..self.len * self.token_elems]
    }

    /// The filled V payload (see [`k_filled`](Self::k_filled)).
    pub fn v_filled(&self) -> &[f32] {
        &self.v[..self.len * self.token_elems]
    }

    /// Token capacity of the block.
    pub fn block_size(&self) -> usize {
        self.k.len() / self.token_elems
    }

    /// Elements per token row (the stream's `heads * head_dim`).
    pub fn token_elems(&self) -> usize {
        self.token_elems
    }

    /// Tokens currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True once every slot is filled — the block is sealed and must not
    /// be mutated again.
    pub fn is_full(&self) -> bool {
        self.len == self.block_size()
    }

    /// Append one token's K and V rows (each `token_elems` long).
    ///
    /// # Panics
    ///
    /// Panics if the block is full or the row lengths are wrong.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(!self.is_full(), "push into a sealed (full) block");
        assert_eq!(k_row.len(), self.token_elems, "k_row length != token_elems");
        assert_eq!(v_row.len(), self.token_elems, "v_row length != token_elems");
        let o = self.len * self.token_elems;
        self.k[o..o + self.token_elems].copy_from_slice(k_row);
        self.v[o..o + self.token_elems].copy_from_slice(v_row);
        self.len += 1;
    }

    /// Append token `t` of a `[heads, tokens, head_dim]` chunk-slab pair
    /// directly into block storage — the bulk-ingest counterpart of
    /// [`push`](Self::push).  Head `h`'s row slice lives at
    /// `h * tokens * head_dim + t * head_dim` in each slab; the block
    /// stores it at the same `[heads, head_dim]` per-token layout `push`
    /// writes, so chunked ingest is bitwise identical to gathering the
    /// token's row first and pushing it.
    ///
    /// # Panics
    ///
    /// Panics if the block is full, `head_dim` does not divide
    /// `token_elems`, or the slabs are not `tokens` tokens long.
    pub fn push_strided(
        &mut self,
        k_slab: &[f32],
        v_slab: &[f32],
        t: usize,
        tokens: usize,
        head_dim: usize,
    ) {
        assert!(!self.is_full(), "push into a sealed (full) block");
        assert!(
            head_dim > 0 && self.token_elems % head_dim == 0,
            "head_dim {head_dim} does not divide token_elems {}",
            self.token_elems
        );
        assert_eq!(k_slab.len(), tokens * self.token_elems, "k_slab length mismatch");
        assert_eq!(v_slab.len(), tokens * self.token_elems, "v_slab length mismatch");
        assert!(t < tokens, "token {t} out of chunk range {tokens}");
        let heads = self.token_elems / head_dim;
        let o = self.len * self.token_elems;
        for h in 0..heads {
            let src = h * tokens * head_dim + t * head_dim;
            let dst = o + h * head_dim;
            self.k[dst..dst + head_dim].copy_from_slice(&k_slab[src..src + head_dim]);
            self.v[dst..dst + head_dim].copy_from_slice(&v_slab[src..src + head_dim]);
        }
        self.len += 1;
    }

    /// The K row of token `slot` (`slot < len`).
    pub fn k_token(&self, slot: usize) -> &[f32] {
        assert!(slot < self.len, "token slot {slot} out of range (len {})", self.len);
        &self.k[slot * self.token_elems..(slot + 1) * self.token_elems]
    }

    /// The V row of token `slot` (`slot < len`).
    pub fn v_token(&self, slot: usize) -> &[f32] {
        assert!(slot < self.len, "token slot {slot} out of range (len {})", self.len);
        &self.v[slot * self.token_elems..(slot + 1) * self.token_elems]
    }

    /// FNV-1a over the filled K then V bit patterns (plus the length).
    /// Deterministic across runs and processes — equal contents always
    /// hash equal, so identical prompt prefixes land on identical trie
    /// paths.
    pub fn content_hash(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        // 4 bytes per element (f32 bit patterns), not a widened u64 —
        // this runs on the append hot path at every block seal
        let mut mix = |word: u32| {
            for byte in word.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        mix(self.len as u32);
        let filled = self.len * self.token_elems;
        for &x in &self.k[..filled] {
            mix(x.to_bits());
        }
        for &x in &self.v[..filled] {
            mix(x.to_bits());
        }
        h
    }

    /// Bitwise content equality over the filled region — the collision
    /// guard behind every hash hit.
    pub fn content_eq(&self, other: &Self) -> bool {
        let filled = self.len * self.token_elems;
        self.len == other.len
            && self.token_elems == other.token_elems
            && bits_eq(&self.k[..filled], &other.k[..filled])
            && bits_eq(&self.v[..filled], &other.v[..filled])
    }
}

/// Bit-pattern slice equality (`-0.0 != 0.0`, `NaN == NaN` at equal bits —
/// the identity the dedup cache needs, not IEEE semantics).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(block_size: usize, token_elems: usize) -> KvBlock {
        KvBlock::from_storage(
            vec![0.0; block_size * token_elems],
            vec![0.0; block_size * token_elems],
            token_elems,
        )
    }

    #[test]
    fn push_and_read_back_tokens() {
        let mut b = block(3, 2);
        assert!(b.is_empty() && !b.is_full());
        b.push(&[1.0, 2.0], &[3.0, 4.0]);
        b.push(&[5.0, 6.0], &[7.0, 8.0]);
        assert_eq!(b.len(), 2);
        assert_eq!(b.k_token(0), &[1.0, 2.0]);
        assert_eq!(b.v_token(1), &[7.0, 8.0]);
        b.push(&[0.0, 0.0], &[0.0, 0.0]);
        assert!(b.is_full());
    }

    #[test]
    #[should_panic]
    fn push_into_full_block_panics() {
        let mut b = block(1, 2);
        b.push(&[1.0, 2.0], &[3.0, 4.0]);
        b.push(&[5.0, 6.0], &[7.0, 8.0]);
    }

    #[test]
    fn hash_depends_on_content_and_length() {
        let mut a = block(2, 2);
        let mut b = block(2, 2);
        a.push(&[1.0, 2.0], &[3.0, 4.0]);
        b.push(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(a.content_hash(), b.content_hash());
        assert!(a.content_eq(&b));
        b.push(&[9.0, 9.0], &[9.0, 9.0]);
        assert_ne!(a.content_hash(), b.content_hash());
        assert!(!a.content_eq(&b));
        // same length, different bytes
        let mut c = block(2, 2);
        c.push(&[1.0, 2.5], &[3.0, 4.0]);
        assert_ne!(a.content_hash(), c.content_hash());
        assert!(!a.content_eq(&c));
    }

    #[test]
    fn hash_ignores_unfilled_slots() {
        let mut dirty = KvBlock::from_storage(vec![7.0; 4], vec![7.0; 4], 2);
        let mut clean = block(2, 2);
        dirty.push(&[1.0, 2.0], &[3.0, 4.0]);
        clean.push(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(dirty.content_hash(), clean.content_hash());
        assert!(dirty.content_eq(&clean));
    }

    #[test]
    fn push_strided_matches_gathered_push_bitwise() {
        // 2 heads × head_dim 2, a 3-token chunk in [heads, tokens,
        // head_dim] layout vs pushing each token's gathered row
        let tokens = 3;
        let head_dim = 2;
        let k_slab: Vec<f32> = (0..tokens * 4).map(|x| x as f32 * 0.5).collect();
        let v_slab: Vec<f32> = (0..tokens * 4).map(|x| -(x as f32)).collect();
        let mut strided = block(3, 4);
        let mut pushed = block(3, 4);
        for t in 0..tokens {
            strided.push_strided(&k_slab, &v_slab, t, tokens, head_dim);
            let gather = |slab: &[f32]| -> Vec<f32> {
                (0..2)
                    .flat_map(|h| {
                        let o = h * tokens * head_dim + t * head_dim;
                        slab[o..o + head_dim].to_vec()
                    })
                    .collect()
            };
            pushed.push(&gather(&k_slab), &gather(&v_slab));
        }
        assert!(strided.content_eq(&pushed));
        assert_eq!(strided.content_hash(), pushed.content_hash());
    }

    #[test]
    fn negative_zero_is_distinct() {
        let mut a = block(1, 1);
        let mut b = block(1, 1);
        a.push(&[0.0], &[0.0]);
        b.push(&[-0.0], &[0.0]);
        assert!(!a.content_eq(&b), "-0.0 must not dedupe against 0.0");
    }
}
