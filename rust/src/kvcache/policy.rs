//! Cache configuration: block geometry, capacity, the eviction policy,
//! and the demotion tier ladder.

use super::tier::TierLadder;
use std::path::PathBuf;

/// What happens to blocks as streams grow and the pool fills.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Streams keep their whole history; sealed blocks are retained in
    /// the prefix index after streams close and evicted
    /// least-recently-used (and only when unreferenced) once the pool
    /// hits capacity.
    Lru,
    /// Unbounded-stream mode: each stream keeps only its last `window`
    /// tokens (queries compute over the window; front blocks are released
    /// as they fall fully outside it).  Sealed blocks still dedupe
    /// through the prefix index, with the same LRU capacity eviction.
    SlidingWindow {
        /// Window length in tokens (clamped to ≥ 1).
        window: usize,
    },
}

impl EvictionPolicy {
    /// The sliding-window length, if this policy has one.
    pub fn window(&self) -> Option<usize> {
        match self {
            Self::Lru => None,
            Self::SlidingWindow { window } => Some((*window).max(1)),
        }
    }
}

/// Configuration for a [`KvCache`](super::KvCache).
#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    /// Tokens per block.  Smaller blocks share finer-grained prefixes but
    /// carry more per-block bookkeeping; 16 is a reasonable default.
    pub block_size: usize,
    /// Max resident blocks across all streams + the prefix index
    /// (0 = unbounded).  A soft cap: live streams always get a block, and
    /// LRU eviction of unreferenced index entries brings the count back
    /// down.
    pub capacity_blocks: usize,
    /// Eviction policy.
    pub policy: EvictionPolicy,
    /// Route one-shot batched requests (`HeadsRequest` K/V slabs)
    /// through the cache too: each request's slabs are content-hashed
    /// into the same prefix-index paths streams use, so a resubmitted or
    /// prompt-shared batched request materialises its head views from
    /// shared blocks instead of storing the payload again.  Batch chains
    /// always keep the request's full `seq` tokens (the sliding window,
    /// if any, applies to decode streams only — a one-shot request has a
    /// fixed length, so truncating it would change served bytes).
    ///
    /// **Pair this with a finite [`capacity_blocks`](Self::capacity_blocks).**
    /// Batch-ingested blocks are retained by the index for future replays
    /// and have no window-reclaim path, so LRU capacity pressure is the
    /// only thing bounding them; with capacity 0 (unbounded) a stream of
    /// non-repeating requests grows the cache without limit.  The CLI
    /// applies a default cap when `--kv-batch-dedupe` is set alone.
    pub batch_dedupe: bool,
    /// Demotion rungs below hot (all off by default).  With any rung
    /// enabled, capacity pressure demotes LRU index-only blocks one tier
    /// at a time (f32 → f16 → int8 → spilled, skipping disabled rungs)
    /// instead of dropping them; with all rungs off the cache is bitwise
    /// identical to the pre-tier implementation.  Only meaningful
    /// together with a finite [`capacity_blocks`](Self::capacity_blocks)
    /// (no pressure, no demotion), except that a spill directory also
    /// enables explicit [`KvCache::spill_index`](super::KvCache::spill_index)
    /// snapshots and warm restarts.
    pub tiers: TierLadder,
}

impl KvCacheConfig {
    /// `block_size`-token blocks, unbounded capacity, [`EvictionPolicy::Lru`].
    pub fn new(block_size: usize) -> Self {
        Self {
            block_size: block_size.max(1),
            capacity_blocks: 0,
            policy: EvictionPolicy::Lru,
            batch_dedupe: false,
            tiers: TierLadder::none(),
        }
    }

    pub fn with_capacity_blocks(mut self, capacity: usize) -> Self {
        self.capacity_blocks = capacity;
        self
    }

    pub fn with_policy(mut self, policy: EvictionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Convenience: switch to [`EvictionPolicy::SlidingWindow`].
    pub fn with_window(self, window: usize) -> Self {
        self.with_policy(EvictionPolicy::SlidingWindow { window })
    }

    /// Enable [`batch_dedupe`](Self::batch_dedupe) — batch-path prefix
    /// sharing for one-shot request slabs.
    pub fn with_batch_dedupe(mut self, on: bool) -> Self {
        self.batch_dedupe = on;
        self
    }

    /// Set the demotion [`TierLadder`].
    pub fn with_tiers(mut self, tiers: TierLadder) -> Self {
        self.tiers = tiers;
        self
    }

    /// Convenience: enable the spill rung at `dir` (keeping any
    /// already-configured quantised rungs).
    pub fn with_spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.tiers.spill_dir = Some(dir.into());
        self
    }

    /// The per-stream sliding window, if the policy has one.
    pub fn window(&self) -> Option<usize> {
        self.policy.window()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let cfg = KvCacheConfig::new(8)
            .with_capacity_blocks(64)
            .with_window(512)
            .with_batch_dedupe(true);
        assert_eq!(cfg.block_size, 8);
        assert_eq!(cfg.capacity_blocks, 64);
        assert_eq!(cfg.window(), Some(512));
        assert!(cfg.batch_dedupe);
        assert_eq!(KvCacheConfig::new(8).window(), None);
        assert!(!KvCacheConfig::new(8).batch_dedupe);
        assert!(!KvCacheConfig::new(8).tiers.enabled(), "tiers default off");
        let tiered = KvCacheConfig::new(8)
            .with_tiers(TierLadder::none().with_f16(true))
            .with_spill_dir("/tmp/spill");
        assert!(tiered.tiers.f16 && tiered.tiers.spill_dir.is_some());
    }

    #[test]
    fn degenerate_values_clamp() {
        assert_eq!(KvCacheConfig::new(0).block_size, 1);
        assert_eq!(EvictionPolicy::SlidingWindow { window: 0 }.window(), Some(1));
    }
}
