//! The tier ladder: lossy in-RAM representations of cold sealed blocks.
//!
//! A sealed [`KvBlock`] starts **hot** (exact f32).  Under capacity
//! pressure the cache demotes index-only blocks one rung at a time
//! instead of dropping them — f32 → f16 → int8 → spilled-to-disk —
//! trading bounded dequantisation error (or a disk read) for resident
//! bytes, the same controlled-approximation trade the paper's sketched
//! score matrices make one layer up.  [`TierLadder`] says which rungs are
//! enabled; [`QuantBlock`] is the in-RAM payload of the f16/int8 rungs;
//! the spilled rung lives in [`BlockStore`](super::store::BlockStore).
//!
//! **Codec contracts** (pinned by `rust/tests/kv_tiers.rs`):
//!
//! * f16 is IEEE binary16 with round-to-nearest-even: exactly-representable
//!   values round-trip bitwise, everything else within `2^-11` relative
//!   error (half the 10-bit mantissa ulp).
//! * int8 uses a per-payload absmax-derived scale snapped **up** to a
//!   power of two (`scale = 2^⌈log2(absmax/127)⌉`), so `x/scale` and
//!   `q*scale` are exact f32 operations: element-wise error is ≤ scale/2,
//!   and quantise→dequantise→quantise is *exactly* idempotent (data and
//!   scale bitwise stable) — an already-cold block never drifts further.
//! * Dequantised views are written straight into the caller's scratch
//!   matrices by [`QuantBlock::dequant_head_into`]; nothing lossy is ever
//!   re-inserted into the prefix index, so a quantised block can still be
//!   *verified* against a freshly sealed candidate
//!   ([`QuantBlock::matches_quantised`]) by re-encoding the candidate —
//!   deterministic codecs make that comparison exact.

use super::block::KvBlock;
use std::path::PathBuf;
use std::sync::Arc;

/// The representation rung a cached block currently occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockTier {
    /// Exact f32 — the only tier chains read from without decoding.
    F32,
    /// IEEE binary16 payload, half the bytes.
    F16,
    /// Per-payload absmax int8, a quarter of the bytes.
    Int8,
    /// Exact bytes on disk only (content-addressed; see
    /// [`BlockStore`](super::store::BlockStore)).
    Spilled,
}

/// Which demotion rungs are enabled (all off by default — the tiers-off
/// cache is bitwise identical to one built before tiers existed).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TierLadder {
    /// Demote hot index-only blocks to f16 under capacity pressure.
    pub f16: bool,
    /// Demote to int8 (from f16 when both are enabled, else from hot).
    pub int8: bool,
    /// Spill exact f32 bytes to this content-addressed directory and
    /// keep demoting quantised blocks down to disk-only entries.
    pub spill_dir: Option<PathBuf>,
}

impl TierLadder {
    /// The all-off ladder (same as `Default`).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_f16(mut self, on: bool) -> Self {
        self.f16 = on;
        self
    }

    pub fn with_int8(mut self, on: bool) -> Self {
        self.int8 = on;
        self
    }

    pub fn with_spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// True when any rung below hot is enabled — the cache only takes
    /// the tiered pressure path (and pays its bookkeeping) when this is.
    pub fn enabled(&self) -> bool {
        self.f16 || self.int8 || self.spill_dir.is_some()
    }

    /// The next *quantised* rung below `from`, or `None` when the block
    /// should fall through to the spill store (or be dropped).
    pub fn next_quant(&self, from: BlockTier) -> Option<BlockTier> {
        match from {
            BlockTier::F32 if self.f16 => Some(BlockTier::F16),
            BlockTier::F32 | BlockTier::F16 if self.int8 => Some(BlockTier::Int8),
            _ => None,
        }
    }

    /// Parse a `--kv-tiers` value: comma-separated rung names out of
    /// `f16`, `int8` (e.g. `"f16,int8"`).  The spill rung is a separate
    /// flag (`--kv-spill-dir`) because it needs a path.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut ladder = Self::none();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok {
                "f16" => ladder.f16 = true,
                "int8" => ladder.int8 = true,
                other => return Err(format!("unknown KV tier {other:?} (expected f16 or int8)")),
            }
        }
        Ok(ladder)
    }
}

/// Convert an f32 to IEEE binary16 bits, round-to-nearest-even (the
/// hardware conversion semantics; carries propagate into the exponent,
/// overflow saturates to ±inf, NaN stays NaN).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN — keep a quiet bit so a NaN payload never collapses
        // to the inf encoding
        let nan = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan | ((man >> 13) as u16 & 0x01ff);
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow: ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows past the smallest subnormal: ±0
        }
        // subnormal: shift the implicit-1 significand into place
        let sig = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let mut out = sig >> shift;
        let dropped = sig & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if dropped > half || (dropped == half && (out & 1) == 1) {
            out += 1; // may round up into the smallest normal — encoding stays valid
        }
        return sign | out as u16;
    }
    let mut out = ((e as u32) << 10) | (man >> 13);
    let dropped = man & 0x1fff;
    if dropped > 0x1000 || (dropped == 0x1000 && (out & 1) == 1) {
        out += 1; // mantissa carry may bump the exponent; 0x7c00 (inf) is then correct
    }
    sign | out as u16
}

/// Convert IEEE binary16 bits back to f32 (exact — every f16 value is
/// representable in f32).  The decoder now lives with the dispatched
/// microkernels so the AVX2 dequant path can share its semantics;
/// re-exported here to keep the tier module's public surface stable.
pub use crate::tensor::kernels::f16_bits_to_f32;

/// Smallest power of two ≥ `absmax / 127` (0 for an all-zero payload).
/// A power-of-two scale makes `x / scale` and `q * scale` exact f32
/// operations — the property the idempotence contract rests on.
fn po2_scale(absmax: f32) -> f32 {
    // all-zero payloads (and out-of-contract non-finite ones) encode as
    // scale 0: every element quantises and dequantises to exactly 0
    if !(absmax > 0.0) || !absmax.is_finite() {
        return 0.0;
    }
    let target = absmax / 127.0;
    let mut scale = 1.0f32;
    while scale < target {
        scale *= 2.0;
    }
    while scale * 0.5 >= target {
        scale *= 0.5;
    }
    scale
}

/// One quantised K or V payload.
#[derive(Debug, PartialEq)]
enum QuantPayload {
    F16(Vec<u16>),
    Int8 {
        data: Vec<i8>,
        /// Power-of-two absmax-derived scale (see [`po2_scale`]); 0 for
        /// an all-zero payload.
        scale: f32,
    },
}

impl QuantPayload {
    fn encode(xs: &[f32], tier: BlockTier) -> Self {
        match tier {
            BlockTier::F16 => Self::F16(xs.iter().map(|&x| f32_to_f16_bits(x)).collect()),
            BlockTier::Int8 => {
                let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let scale = po2_scale(absmax);
                if scale == 0.0 {
                    return Self::Int8 { data: vec![0; xs.len()], scale: 0.0 };
                }
                let inv = 1.0 / scale; // power of two: exact
                let data = xs.iter().map(|&x| (x * inv).round() as i8).collect();
                Self::Int8 { data, scale }
            }
            other => unreachable!("no quantised payload for tier {other:?}"),
        }
    }

    #[inline]
    fn get(&self, i: usize) -> f32 {
        match self {
            Self::F16(data) => f16_bits_to_f32(data[i]),
            Self::Int8 { data, scale } => data[i] as f32 * scale,
        }
    }

    /// Decode a contiguous element range into caller scratch on the
    /// dispatched dequant kernels.  Both codecs are exact (f16 → f32 is
    /// lossless; the int8 scale is a power of two), so every ISA
    /// variant decodes to identical bits.
    fn decode_into(&self, range: std::ops::Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(range.len(), out.len());
        let kt = crate::tensor::kernels::active();
        match self {
            Self::F16(data) => (kt.dequant_f16)(&data[range], out),
            Self::Int8 { data, scale } => (kt.dequant_i8)(&data[range], *scale, out),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Self::F16(data) => data.len() * 2,
            Self::Int8 { data, .. } => data.len() + std::mem::size_of::<f32>(),
        }
    }

    fn len(&self) -> usize {
        match self {
            Self::F16(data) => data.len(),
            Self::Int8 { data, .. } => data.len(),
        }
    }
}

/// A sealed block demoted to a lossy in-RAM representation (f16 or
/// int8).  Immutable like every sealed block; shared as
/// `Arc<QuantBlock>` between the prefix index and any chains that hit
/// it.  Reads decode into caller scratch via
/// [`Self::dequant_head_into`] — the decoded f32 view lives only as
/// long as the query's scratch buffers and is never cached or
/// re-hashed.
#[derive(Debug)]
pub struct QuantBlock {
    k: QuantPayload,
    v: QuantPayload,
    len: usize,
    token_elems: usize,
}

impl QuantBlock {
    /// Quantise a sealed (full) block's filled K/V payloads to `tier`
    /// (must be [`BlockTier::F16`] or [`BlockTier::Int8`]).
    pub fn quantise(block: &KvBlock, tier: BlockTier) -> Self {
        Self {
            k: QuantPayload::encode(block.k_filled(), tier),
            v: QuantPayload::encode(block.v_filled(), tier),
            len: block.len(),
            token_elems: block.token_elems(),
        }
    }

    /// Re-encode this block one rung colder (f16 → int8): decode, then
    /// quantise the decoded values.  The int8 scale is derived from the
    /// *decoded* absmax, so error stays ≤ scale/2 of what this block
    /// already holds.
    pub fn requantise(&self, tier: BlockTier) -> Self {
        let (k, v) = self.dequantise();
        Self {
            k: QuantPayload::encode(&k, tier),
            v: QuantPayload::encode(&v, tier),
            len: self.len,
            token_elems: self.token_elems,
        }
    }

    /// The rung this payload occupies ([`BlockTier::F16`] or
    /// [`BlockTier::Int8`]).
    pub fn tier(&self) -> BlockTier {
        match self.k {
            QuantPayload::F16(_) => BlockTier::F16,
            QuantPayload::Int8 { .. } => BlockTier::Int8,
        }
    }

    /// Tokens stored (always the full block size — only sealed blocks
    /// are demoted).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn token_elems(&self) -> usize {
        self.token_elems
    }

    /// Resident payload bytes (K + V + scales) — what the pool's
    /// quantised-bytes ledger tracks.
    pub fn payload_bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    /// Decode head columns `[offset, offset + head_dim)` of token `slot`
    /// into `k_out` / `v_out` (each `head_dim` long) — the fused
    /// gather + dequantise read: the range arithmetic picks the token's
    /// head slice and the dispatched dequant kernel decodes it straight
    /// into caller scratch (`vcvtph2ps` / `vpmovsxbd` on AVX2), with no
    /// intermediate full-block decode.  The decoded values exist only
    /// in the caller's scratch.
    pub fn dequant_head_into(
        &self,
        slot: usize,
        offset: usize,
        head_dim: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        assert!(slot < self.len, "token slot {slot} out of range (len {})", self.len);
        assert!(offset + head_dim <= self.token_elems, "head columns out of range");
        let start = slot * self.token_elems + offset;
        self.k.decode_into(start..start + head_dim, k_out);
        self.v.decode_into(start..start + head_dim, v_out);
    }

    /// Decode the full K and V payloads (requantisation and tests).
    pub fn dequantise(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.k.len();
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        self.k.decode_into(0..n, &mut k);
        self.v.decode_into(0..n, &mut v);
        (k, v)
    }

    /// Would `candidate` quantise to exactly this payload?  The
    /// collision/verification guard for hash hits on a quantised entry:
    /// the codecs are deterministic, so re-encoding the freshly sealed
    /// candidate and comparing payloads bitwise is an exact test — a
    /// hash collision (or content drift) degrades to a miss, never to a
    /// silently shared wrong block.
    pub fn matches_quantised(&self, candidate: &KvBlock) -> bool {
        self.len == candidate.len()
            && self.token_elems == candidate.token_elems()
            && self.k == QuantPayload::encode(candidate.k_filled(), self.tier())
            && self.v == QuantPayload::encode(candidate.v_filled(), self.tier())
    }
}

/// What a trie node holds: the rung its block currently occupies.
/// `Spilled` carries no payload — the exact bytes live in the
/// [`BlockStore`](super::store::BlockStore) under the node's content
/// hash, and a hit re-reads + re-verifies them from disk.
#[derive(Clone, Debug)]
pub enum CacheEntry {
    Hot(Arc<KvBlock>),
    Quant(Arc<QuantBlock>),
    Spilled,
}

impl CacheEntry {
    pub fn tier(&self) -> BlockTier {
        match self {
            Self::Hot(_) => BlockTier::F32,
            Self::Quant(q) => q.tier(),
            Self::Spilled => BlockTier::Spilled,
        }
    }

    pub fn is_hot(&self) -> bool {
        matches!(self, Self::Hot(_))
    }

    /// True when nothing outside the index references the payload (a
    /// disk-only entry trivially qualifies) — the demotion/eviction
    /// precondition.
    pub fn ram_unreferenced(&self) -> bool {
        match self {
            Self::Hot(b) => Arc::strong_count(b) == 1,
            Self::Quant(q) => Arc::strong_count(q) == 1,
            Self::Spilled => true,
        }
    }

    /// The hot block, if that is what this entry holds (test + release
    /// plumbing).
    pub fn into_hot(self) -> Option<Arc<KvBlock>> {
        match self {
            Self::Hot(b) => Some(b),
            _ => None,
        }
    }
}

/// A chain's reference to one of its sealed blocks: exact (hot) or
/// quantised.  Never `Spilled` — a chain holding a reference means the
/// payload has ≥ 2 strong refs, and demotion requires RAM-unreferenced
/// entries, so anything a live chain can see stays in RAM.  That is the
/// invariant that keeps
/// [`StreamChain::gather_head_into`](super::StreamChain::gather_head_into)
/// infallible and free of disk I/O.
#[derive(Clone, Debug)]
pub enum SealedRef {
    Hot(Arc<KvBlock>),
    Quant(Arc<QuantBlock>),
}

impl SealedRef {
    /// Tokens stored in the referenced block.
    pub fn len(&self) -> usize {
        match self {
            Self::Hot(b) => b.len(),
            Self::Quant(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block_from(k: &[f32], v: &[f32], token_elems: usize) -> KvBlock {
        let mut b = KvBlock::from_storage(vec![0.0; k.len()], vec![0.0; v.len()], token_elems);
        for t in 0..k.len() / token_elems {
            b.push(
                &k[t * token_elems..(t + 1) * token_elems],
                &v[t * token_elems..(t + 1) * token_elems],
            );
        }
        b
    }

    #[test]
    fn f16_round_trips_exactly_representable_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 1024.0, 65504.0, 0.0009765625, 2.0f32.powi(-24)] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "f16 round trip of {x}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY, "overflow saturates");
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-9)), 0.0, "underflow flushes");
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 2049 is exactly halfway between the f16-representable 2048 and
        // 2050 → ties to even (2048); 2051 is halfway to 2052 → 2052
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2049.0)), 2048.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2051.0)), 2052.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2049.1)), 2050.0, "above the tie rounds up");
    }

    #[test]
    fn int8_scale_is_a_power_of_two_covering_absmax() {
        for &absmax in &[1.0f32, 127.0, 3.7, 1e-3, 1e6] {
            let s = po2_scale(absmax);
            assert!(s > 0.0);
            assert_eq!(s.to_bits() & 0x007f_ffff, 0, "scale must be a power of two");
            assert!(absmax / s <= 127.0, "absmax {absmax} must fit in ±127 steps");
            assert!(absmax / s > 63.5, "scale must be the smallest covering power of two");
        }
        assert_eq!(po2_scale(0.0), 0.0);
    }

    #[test]
    fn quantise_error_within_half_scale_and_idempotent() {
        let k: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin() * 5.0).collect();
        let v: Vec<f32> = (0..16).map(|i| (i as f32 * 0.91).cos() * -3.0).collect();
        let block = block_from(&k, &v, 4);
        for tier in [BlockTier::F16, BlockTier::Int8] {
            let q = QuantBlock::quantise(&block, tier);
            assert_eq!(q.tier(), tier);
            let (dk, dv) = q.dequantise();
            match tier {
                BlockTier::Int8 => {
                    let QuantPayload::Int8 { scale, .. } = &q.k else { unreachable!() };
                    for (x, y) in k.iter().zip(&dk) {
                        assert!((x - y).abs() <= *scale / 2.0, "int8 error bound: {x} vs {y}");
                    }
                }
                _ => {
                    for (x, y) in k.iter().zip(&dk) {
                        assert!((x - y).abs() <= x.abs() * 2.0f32.powi(-11), "f16 bound");
                    }
                }
            }
            // idempotence: re-quantising the dequantised block is bitwise
            // stable (payloads AND scales)
            let again = QuantBlock::quantise(&block_from(&dk, &dv, 4), tier);
            assert_eq!(q.k, again.k, "{tier:?} K payload must be idempotent");
            assert_eq!(q.v, again.v, "{tier:?} V payload must be idempotent");
        }
    }

    #[test]
    fn matches_quantised_verifies_and_rejects() {
        let k: Vec<f32> = (0..8).map(|i| i as f32 * 0.3).collect();
        let block = block_from(&k, &k, 2);
        for tier in [BlockTier::F16, BlockTier::Int8] {
            let q = QuantBlock::quantise(&block, tier);
            assert!(q.matches_quantised(&block), "{tier:?} must match its source");
            let mut other = k.clone();
            other[3] += 1.0; // well beyond any quantisation step
            let perturbed = block_from(&other, &k, 2);
            assert!(!q.matches_quantised(&perturbed), "{tier:?} must reject different content");
        }
    }

    #[test]
    fn dequant_head_into_matches_full_decode() {
        let k: Vec<f32> = (0..12).map(|i| i as f32 * 1.1).collect();
        let v: Vec<f32> = (0..12).map(|i| -(i as f32) * 0.7).collect();
        let block = block_from(&k, &v, 4); // 3 tokens × (2 heads × head_dim 2)
        let q = QuantBlock::quantise(&block, BlockTier::F16);
        let (dk, dv) = q.dequantise();
        let mut kh = [0.0f32; 2];
        let mut vh = [0.0f32; 2];
        q.dequant_head_into(1, 2, 2, &mut kh, &mut vh); // token 1, head 1
        assert_eq!(kh, dk[6..8], "head view must slice the same decode");
        assert_eq!(vh, dv[6..8]);
    }

    #[test]
    fn ladder_rungs_and_parse() {
        let l = TierLadder::parse("f16,int8").unwrap();
        assert!(l.f16 && l.int8 && l.enabled());
        assert_eq!(l.next_quant(BlockTier::F32), Some(BlockTier::F16));
        assert_eq!(l.next_quant(BlockTier::F16), Some(BlockTier::Int8));
        assert_eq!(l.next_quant(BlockTier::Int8), None);
        let int8_only = TierLadder::parse(" int8 ").unwrap();
        assert_eq!(int8_only.next_quant(BlockTier::F32), Some(BlockTier::Int8));
        assert!(TierLadder::parse("f8").is_err());
        assert!(!TierLadder::none().enabled());
        assert!(TierLadder::none().with_spill_dir("/tmp/x").enabled());
    }
}
