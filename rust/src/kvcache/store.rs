//! The spill tier: a content-addressed on-disk block store.
//!
//! Exact f32 block bytes are archived under their FNV-1a content hash —
//! the same digest the [`PrefixIndex`](super::PrefixIndex) keys on, and
//! the same digest-addressing OCI registries use for blobs: the address
//! *is* the checksum, so a read can always re-verify what it got.  Layout
//! under the store directory:
//!
//! ```text
//! spill_dir/
//!   MANIFEST            # append-only text: one line per spilled entry
//!   blocks/
//!     <hash:016x>.kvb   # magic + geometry header + raw K then V f32 LE
//! ```
//!
//! **Write-once exact archive.** A block's file is written at its *first*
//! demotion, while the exact f32 bytes still exist in RAM.  Later rungs
//! (f16/int8) never write — they only check [`BlockStore::contains`] —
//! so a block that sinks all the way to the spilled rung always
//! rehydrates bitwise-identical to what was sealed, no matter how lossy
//! its in-RAM representation got in between.  Writes go through a `.tmp` + atomic rename, so concurrent
//! writers (two processes sharing a store) race benignly: same hash,
//! same bytes.
//!
//! **Digest re-verified on read.** [`BlockStore::read`] validates the
//! header, the byte length, and finally recomputes the decoded block's
//! content hash against the address it was fetched under.  A truncated
//! file, a flipped byte, or a missing file all surface as
//! [`SpillError`] — the cache maps that to a *miss* (and a
//! `spill_corrupt` stat bump), never a panic, never silent wrong bytes.
//!
//! The manifest records each spilled entry's full trie path (ancestor
//! hashes + own hash) so a fresh [`KvCache`](super::KvCache) over the
//! same directory can re-register every entry at the right prefix
//! position — warm restart — and two live caches over one directory
//! share blocks across processes.  Lines are self-describing and
//! independently parseable; unreadable lines are skipped (a torn
//! append degrades to a forgotten entry, which is just a miss).

use super::block::KvBlock;
use std::fs;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

/// File magic for block files (`.kvb`).
const BLOCK_MAGIC: &[u8; 4] = b"KVB1";
/// First line of a fresh manifest.
const MANIFEST_HEADER: &str = "KVMANIFEST v1";

/// Why a spill-store read could not produce a verified block.  Every
/// variant degrades to a cache miss at the call site.
#[derive(Debug)]
pub enum SpillError {
    /// The file is missing or unreadable (I/O level).
    Io(io::Error),
    /// The file was read but failed validation (bad magic, wrong
    /// geometry, truncation, or digest mismatch).
    Corrupt(&'static str),
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "spill read failed: {e}"),
            Self::Corrupt(why) => write!(f, "spill block corrupt: {why}"),
        }
    }
}

impl std::error::Error for SpillError {}

impl From<io::Error> for SpillError {
    fn from(e: io::Error) -> Self {
        Self::Io(e)
    }
}

/// One manifest line: a spilled entry's identity and trie position.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Content hash — the block file's address and expected digest.
    pub hash: u64,
    /// Tokens in the block (always the sealing cache's `block_size`).
    pub len: usize,
    /// f32 elements per token row.
    pub token_elems: usize,
    /// Ancestor content hashes from the trie root (excluding `hash`).
    pub path: Vec<u64>,
}

/// Handle on one spill directory.  Cheap to construct; all state lives
/// on disk, which is what makes warm restarts and cross-process sharing
/// work without coordination.
#[derive(Debug)]
pub struct BlockStore {
    blocks_dir: PathBuf,
    manifest_path: PathBuf,
}

impl BlockStore {
    /// Open (creating if needed) the store at `dir`.
    pub fn open(dir: &Path) -> io::Result<Self> {
        let blocks_dir = dir.join("blocks");
        fs::create_dir_all(&blocks_dir)?;
        let manifest_path = dir.join("MANIFEST");
        if !manifest_path.exists() {
            fs::write(&manifest_path, format!("{MANIFEST_HEADER}\n"))?;
        }
        Ok(Self { blocks_dir, manifest_path })
    }

    /// The on-disk path of `hash`'s block file (exposed so the
    /// fault-injection tests can corrupt it in place).
    pub fn block_path(&self, hash: u64) -> PathBuf {
        self.blocks_dir.join(format!("{hash:016x}.kvb"))
    }

    /// Whether `hash`'s exact bytes are archived — the gate for
    /// demoting a quantised block to the spilled rung (which holds no
    /// RAM payload at all).
    pub fn contains(&self, hash: u64) -> bool {
        self.block_path(hash).exists()
    }

    /// Archive `block`'s exact bytes under `hash` and append a manifest
    /// line recording its trie position (`path` = ancestor hashes).  The
    /// block file is written only if absent (content-addressed: equal
    /// hash ⇒ equal verified bytes); the manifest line is appended
    /// unconditionally so the same content spilled at a new prefix
    /// position is restorable at both.  Returns whether a new block
    /// file was written.
    pub fn write(&self, path: &[u64], hash: u64, block: &KvBlock) -> io::Result<bool> {
        let target = self.block_path(hash);
        let mut wrote = false;
        if !target.exists() {
            let tmp = self.blocks_dir.join(format!("{hash:016x}.tmp"));
            let mut buf = Vec::with_capacity(12 + block.len() * block.token_elems() * 8);
            buf.extend_from_slice(BLOCK_MAGIC);
            buf.extend_from_slice(&(block.token_elems() as u32).to_le_bytes());
            buf.extend_from_slice(&(block.len() as u32).to_le_bytes());
            for &x in block.k_filled() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            for &x in block.v_filled() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            fs::write(&tmp, &buf)?;
            fs::rename(&tmp, &target)?; // atomic: readers never see a torn file
            wrote = true;
        }
        let mut line = format!("block {hash:016x} {} {}", block.len(), block.token_elems());
        for h in path {
            line.push_str(&format!(" {h:016x}"));
        }
        line.push('\n');
        let mut manifest =
            fs::OpenOptions::new().create(true).append(true).open(&self.manifest_path)?;
        manifest.write_all(line.as_bytes())?;
        Ok(wrote)
    }

    /// Read and fully verify the block archived under `hash`: header,
    /// geometry (`token_elems`, `block_size`), byte length, and finally
    /// the recomputed content hash against the address.  Any failure is
    /// a [`SpillError`] for the caller to turn into a miss.
    pub fn read(
        &self,
        hash: u64,
        token_elems: usize,
        block_size: usize,
    ) -> Result<KvBlock, SpillError> {
        let mut file = fs::File::open(self.block_path(hash))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        if bytes.len() < 12 {
            return Err(SpillError::Corrupt("truncated header"));
        }
        if &bytes[..4] != BLOCK_MAGIC {
            return Err(SpillError::Corrupt("bad magic"));
        }
        let te = u32::from_le_bytes(bytes[4..8].try_into().expect("4 bytes")) as usize;
        let len = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes")) as usize;
        if te != token_elems || len != block_size {
            return Err(SpillError::Corrupt("geometry mismatch"));
        }
        let elems = len * te;
        if bytes.len() != 12 + elems * 8 {
            return Err(SpillError::Corrupt("payload length mismatch"));
        }
        let decode = |at: usize| -> Vec<f32> {
            bytes[at..at + elems * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
                .collect()
        };
        let block = KvBlock::from_filled(decode(12), decode(12 + elems * 4), te, len);
        if block.content_hash() != hash {
            return Err(SpillError::Corrupt("digest mismatch"));
        }
        Ok(block)
    }

    /// Best-effort removal of `hash`'s block file (corrupt-entry
    /// cleanup, so the next miss re-archives clean bytes).
    pub fn remove(&self, hash: u64) {
        let _ = fs::remove_file(self.block_path(hash));
    }

    /// Parse the manifest into restorable entries, newest line last.
    /// Duplicate `(path, hash)` lines collapse to one; unparseable lines
    /// (torn appends, foreign headers) are skipped — a lost line is just
    /// a future miss, consistent with every other corruption here.
    pub fn load_manifest(&self) -> Vec<ManifestEntry> {
        let Ok(text) = fs::read_to_string(&self.manifest_path) else {
            return Vec::new();
        };
        let mut entries: Vec<ManifestEntry> = Vec::new();
        for line in text.lines() {
            let mut fields = line.split_whitespace();
            if fields.next() != Some("block") {
                continue;
            }
            let Some(hash) = fields.next().and_then(|f| u64::from_str_radix(f, 16).ok()) else {
                continue;
            };
            let Some(len) = fields.next().and_then(|f| f.parse::<usize>().ok()) else {
                continue;
            };
            let Some(token_elems) = fields.next().and_then(|f| f.parse::<usize>().ok()) else {
                continue;
            };
            let path: Option<Vec<u64>> =
                fields.map(|f| u64::from_str_radix(f, 16).ok()).collect();
            let Some(path) = path else {
                continue;
            };
            let entry = ManifestEntry { hash, len, token_elems, path };
            if !entries.contains(&entry) {
                entries.push(entry);
            }
        }
        entries
    }
}

/// A dependency-free stand-in for the `tempfile` crate (the build is
/// offline): a unique directory under [`std::env::temp_dir`], removed
/// recursively on drop.  Shared by the spill unit tests, the
/// `kv_tiers` integration suite, and the `--tiers` bench sweep.
#[doc(hidden)]
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.path);
    }
}

/// Create a fresh uniquely-named directory (`<tag>-<pid>-<seq>` under
/// the system temp dir) that cleans itself up on drop.
#[doc(hidden)]
pub fn tempdir(tag: &str) -> TempDir {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let path = std::env::temp_dir().join(format!("skein-{tag}-{pid}-{seq}"));
    fs::create_dir_all(&path).expect("create temp dir");
    TempDir { path }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sealed_block(fill: impl Fn(usize) -> f32, len: usize, token_elems: usize) -> KvBlock {
        let mut b = KvBlock::from_storage(
            vec![0.0; len * token_elems],
            vec![0.0; len * token_elems],
            token_elems,
        );
        for t in 0..len {
            let k: Vec<f32> = (0..token_elems).map(|e| fill(t * token_elems + e)).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            b.push(&k, &v);
        }
        b
    }

    #[test]
    fn write_read_round_trips_bitwise() {
        let dir = tempdir("store-rt");
        let store = BlockStore::open(dir.path()).unwrap();
        let block = sealed_block(|i| i as f32 * 0.25 - 3.0, 4, 2);
        let hash = block.content_hash();
        assert!(store.write(&[7, 9], hash, &block).unwrap(), "first write creates the file");
        assert!(!store.write(&[7, 9], hash, &block).unwrap(), "re-write is a no-op");
        assert!(store.contains(hash));
        let back = store.read(hash, 2, 4).unwrap();
        assert!(back.content_eq(&block), "rehydrated block must be bitwise identical");
    }

    #[test]
    fn read_rejects_wrong_geometry_and_digest() {
        let dir = tempdir("store-bad");
        let store = BlockStore::open(dir.path()).unwrap();
        let block = sealed_block(|i| i as f32, 2, 3);
        let hash = block.content_hash();
        store.write(&[], hash, &block).unwrap();
        assert!(matches!(store.read(hash, 4, 2), Err(SpillError::Corrupt(_))), "geometry");
        assert!(matches!(store.read(hash ^ 1, 3, 2), Err(SpillError::Io(_))), "missing file");
        // flip one payload byte: digest check must catch it
        let path = store.block_path(hash);
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(store.read(hash, 3, 2), Err(SpillError::Corrupt("digest mismatch"))));
    }

    #[test]
    fn manifest_records_paths_and_dedupes() {
        let dir = tempdir("store-man");
        let store = BlockStore::open(dir.path()).unwrap();
        let a = sealed_block(|i| i as f32 + 1.0, 2, 2);
        let b = sealed_block(|i| i as f32 * 2.0, 2, 2);
        store.write(&[], a.content_hash(), &a).unwrap();
        store.write(&[a.content_hash()], b.content_hash(), &b).unwrap();
        store.write(&[], a.content_hash(), &a).unwrap(); // duplicate line
        let entries = store.load_manifest();
        assert_eq!(entries.len(), 2, "duplicate manifest lines collapse");
        assert_eq!(entries[0].path, Vec::<u64>::new());
        assert_eq!(entries[1].path, vec![a.content_hash()]);
        assert_eq!(entries[1].hash, b.content_hash());
        assert_eq!(entries[1].token_elems, 2);
        // a second store over the same dir sees the same manifest
        let other = BlockStore::open(dir.path()).unwrap();
        assert_eq!(other.load_manifest().len(), 2);
    }

    #[test]
    fn tempdir_is_unique_and_cleaned_up() {
        let a = tempdir("t");
        let b = tempdir("t");
        assert_ne!(a.path(), b.path());
        let kept = a.path().to_path_buf();
        assert!(kept.is_dir());
        drop(a);
        assert!(!kept.exists(), "dropped tempdir must be removed");
    }
}
