//! CLI argument-parsing substrate (no clap offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! switch grammar the `skein` binary uses, with typed accessors, defaults,
//! and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, flags, and positional args.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { flag: String, value: String, expected: &'static str },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(flag) => write!(f, "flag --{flag} expects a value"),
            CliError::BadValue { flag, value, expected } => {
                write!(f, "invalid value for --{flag}: {value:?} ({expected})")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv[1..]`.  The first non-flag token becomes the subcommand;
    /// later bare tokens are positional.  A flag followed by a non-flag
    /// token consumes it as its value; trailing flags become switches.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, CliError> {
        let mut out = Args::default();
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// From the process environment.
    pub fn from_env() -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: name.into(),
                value: v.into(),
                expected: "unsigned integer",
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: name.into(),
                value: v.into(),
                expected: "number",
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: name.into(),
                value: v.into(),
                expected: "unsigned integer",
            }),
        }
    }

    /// Boolean switch (present / `--name true|false`).
    pub fn switch(&self, name: &str) -> bool {
        if self.switches.iter().any(|s| s == name) {
            return true;
        }
        matches!(self.get(name), Some("true" | "1" | "yes"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --method skeinformer --steps 500 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("method"), Some("skeinformer"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 500);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("fig1 --n=1024 --trials=8");
        assert_eq!(a.get_usize("n", 0).unwrap(), 1024);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("mode", "pretrained"), "pretrained");
    }

    #[test]
    fn list_flag() {
        let a = parse("sweep --methods skeinformer,standard,informer,");
        assert_eq!(
            a.get_list("methods").unwrap(),
            vec!["skeinformer".to_string(), "standard".into(), "informer".into()]
        );
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse("x --steps banana");
        assert!(a.get_usize("steps", 1).is_err());
    }

    #[test]
    fn batched_serving_flags() {
        // the grid flags the batched attention engine consumes (`skein
        // serve --engine cpu` and the serving example), plus the global
        // `--pool-size` knob for the persistent worker pool
        let a = parse(
            "serve --engine cpu --batch 16 --heads 8 --seq 2048 --head-dim 64 --pool-size 12",
        );
        assert_eq!(a.get_or("engine", "pjrt"), "cpu");
        assert_eq!(a.get_usize("batch", 1).unwrap(), 16);
        assert_eq!(a.get_usize("heads", 1).unwrap(), 8);
        assert_eq!(a.get_usize("seq", 512).unwrap(), 2048);
        assert_eq!(a.get_usize("head-dim", 32).unwrap(), 64);
        assert_eq!(a.get_usize("pool-size", 0).unwrap(), 12);
        // absent flag keeps the "use the default pool" sentinel
        let b = parse("serve --engine cpu");
        assert_eq!(b.get_usize("pool-size", 0).unwrap(), 0);
    }

    #[test]
    fn positional_args() {
        let a = parse("inspect artifacts/skeinformer_manifest.json extra");
        assert_eq!(a.subcommand.as_deref(), Some("inspect"));
        assert_eq!(a.positional, vec!["artifacts/skeinformer_manifest.json", "extra"]);
    }
}
