//! Reusable temporary storage for the v2 attention API
//! ([`compute_into`](super::AttentionMethod::compute_into)).
//!
//! An [`AttnScratch`] is a per-call handle over recycled buffers: methods
//! draw their temporaries (score strips, sketches, gathered rows, weight
//! vectors) from it instead of allocating, and return them when done.  The
//! buffers themselves come from the worker pool's thread-local stash
//! ([`pool::take_scratch`]/[`pool::recycle_scratch`]) — on the persistent
//! pool workers the stash lives for the pool's lifetime, so the batched
//! B×H hot loop stops allocating once each worker has warmed up.  Dropping
//! an `AttnScratch` returns every buffer it still holds to the stash;
//! buffers checked out and never recycled are simply freed.
//!
//! The take/recycle discipline is LIFO and per-call-site symmetric: a hot
//! loop that performs the same sequence of takes and recycles on every
//! call gets back buffers of exactly the capacities it needs, so
//! steady-state `reserve`/`resize` calls never reallocate.
//!
//! # Examples
//!
//! ```
//! use skeinformer::attention::AttnScratch;
//!
//! let mut scratch = AttnScratch::new();
//! let m = scratch.matrix(4, 8); // zero-filled, recycled backing buffer
//! assert_eq!(m.shape(), (4, 8));
//! scratch.recycle(m); // hand the buffer back for the next temporary
//! let v = scratch.buf(16); // zero-filled f32 buffer
//! assert_eq!(v.len(), 16);
//! scratch.recycle_buf(v);
//! let idx = scratch.idx_buf(); // cleared index buffer
//! assert!(idx.is_empty());
//! scratch.recycle_idx(idx);
//! ```

use crate::pool;
use crate::tensor::Matrix;

/// How many index buffers each thread keeps (f32 buffers are capped by
/// the pool's own per-thread stash instead).
const IDX_KEEP: usize = 8;

/// How many Gumbel-key workspaces each thread keeps (one is enough for
/// every current caller; headroom for nesting).
const PAIR_KEEP: usize = 4;

thread_local! {
    /// Per-thread recycled `Vec<usize>` buffers — thread-local for the
    /// same reason the pool's f32 stash is: an `AttnScratch` handle is
    /// per-call, but the pool workers running the B×H hot loop are
    /// persistent, so index buffers must outlive the handle to be
    /// allocation-free across heads.
    static IDX_STASH: std::cell::RefCell<Vec<Vec<usize>>> =
        const { std::cell::RefCell::new(Vec::new()) };
    /// Per-thread recycled `(key, index)` workspaces for the Gumbel
    /// sampler (`Rng::weighted_without_replacement_into`).
    static PAIR_STASH: std::cell::RefCell<Vec<Vec<(f32, usize)>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Recycled temporary storage for one attention computation.
///
/// See the [module docs](self) for the lifecycle; the short version:
/// `take` ↔ `recycle` pairs are cheap, and on pool workers they are
/// allocation-free after warmup.  The handle itself is stateless — both
/// the f32 and the index buffers live in per-thread stashes — so
/// creating one per call costs nothing.
#[derive(Default)]
pub struct AttnScratch {}

impl AttnScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled f32 buffer of exactly `len` elements, backed by a
    /// recycled allocation when one is available.
    ///
    /// The zero fill is part of the contract — consumers like the masked
    /// Gaussian sketch rely on untouched entries being zero, and it
    /// matches what the allocating path (`vec![0.0; len]` /
    /// `Matrix::zeros`) always paid.  A buffer that will be *fully*
    /// overwritten from a source slice can skip the memset with
    /// [`buf_from`](Self::buf_from).
    pub fn buf(&mut self, len: usize) -> Vec<f32> {
        let mut b = pool::take_scratch(len);
        b.resize(len, 0.0);
        b
    }

    /// A recycled buffer initialised as a copy of `src` — one copy, no
    /// zero fill (the streaming query path's per-head staging uses this).
    pub fn buf_from(&mut self, src: &[f32]) -> Vec<f32> {
        let mut b = pool::take_scratch(src.len());
        b.extend_from_slice(src);
        b
    }

    /// A zero-filled `rows × cols` [`Matrix`] backed by a recycled buffer —
    /// the scratch equivalent of [`Matrix::zeros`].
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.buf(rows * cols))
    }

    /// Return a matrix taken with [`matrix`](Self::matrix) (or any owned
    /// matrix) so its buffer backs the next temporary.
    pub fn recycle(&mut self, m: Matrix) {
        self.recycle_buf(m.into_vec());
    }

    /// Return an f32 buffer to the recycling stash.
    pub fn recycle_buf(&mut self, b: Vec<f32>) {
        pool::recycle_scratch(b);
    }

    /// A cleared `Vec<usize>` for gather/sample index lists, recycled
    /// through this thread's stash.
    pub fn idx_buf(&mut self) -> Vec<usize> {
        match IDX_STASH.with(|s| s.borrow_mut().pop()) {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return an index buffer to this thread's stash.
    pub fn recycle_idx(&mut self, b: Vec<usize>) {
        IDX_STASH.with(|s| {
            let mut stash = s.borrow_mut();
            if stash.len() < IDX_KEEP {
                stash.push(b);
            }
        });
    }

    /// A cleared `(key, index)` workspace for the Gumbel top-k sampler
    /// ([`Rng::weighted_without_replacement_into`](crate::rng::Rng::weighted_without_replacement_into)),
    /// recycled through this thread's stash.
    pub fn pair_buf(&mut self) -> Vec<(f32, usize)> {
        match PAIR_STASH.with(|s| s.borrow_mut().pop()) {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return a Gumbel workspace to this thread's stash.
    pub fn recycle_pair(&mut self, b: Vec<(f32, usize)>) {
        PAIR_STASH.with(|s| {
            let mut stash = s.borrow_mut();
            if stash.len() < PAIR_KEEP {
                stash.push(b);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_zeroed_and_shaped() {
        let mut s = AttnScratch::new();
        let mut b = s.buf(8);
        b.iter().for_each(|x| assert_eq!(*x, 0.0));
        b[3] = 5.0;
        s.recycle_buf(b);
        // a recycled buffer must come back cleared to zero
        let again = s.buf(8);
        assert!(again.iter().all(|x| *x == 0.0));
        s.recycle_buf(again);

        let m = s.matrix(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert!(m.data().iter().all(|x| *x == 0.0));
        s.recycle(m);
    }

    #[test]
    fn buf_from_copies_without_zeroing() {
        let mut s = AttnScratch::new();
        let b = s.buf_from(&[1.0, 2.0, 3.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        s.recycle_buf(b);
        let again = s.buf_from(&[4.0]);
        assert_eq!(again, vec![4.0]);
        s.recycle_buf(again);
    }

    #[test]
    fn idx_buffers_recycle_locally() {
        let mut s = AttnScratch::new();
        let mut i = s.idx_buf();
        i.extend_from_slice(&[1, 2, 3]);
        let cap = i.capacity();
        s.recycle_idx(i);
        let again = s.idx_buf();
        assert!(again.is_empty());
        assert!(again.capacity() >= cap.min(3));
    }

    #[test]
    fn pair_buffers_recycle_locally() {
        let mut s = AttnScratch::new();
        let mut p = s.pair_buf();
        p.extend_from_slice(&[(1.0, 1), (2.0, 2)]);
        let cap = p.capacity();
        s.recycle_pair(p);
        let again = s.pair_buf();
        assert!(again.is_empty(), "recycled pair workspace must come back cleared");
        assert!(again.capacity() >= cap.min(2));
        s.recycle_pair(again);
    }
}
