//! Exact O(n²) softmax attention — the baseline every approximation is
//! measured against (the paper's "Standard" row).

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    RecomputeSession, SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{matmul_into, matmul_nt_into, softmax_rows, Matrix};

/// `softmax(QKᵀ/√p) V`, computed exactly.  Cross-shape (`m×p` queries
/// against `n×p` keys) works out of the box — the softmax is per query
/// row — which is what makes the streaming-decode session exact.
#[derive(Clone, Copy, Debug, Default)]
pub struct Standard;

impl Standard {
    /// The exact attention as a free function (used by benches/tests that
    /// don't want trait dispatch).
    pub fn exact(q: &Matrix, k: &Matrix, v: &Matrix, mask: Option<&[f32]>) -> Matrix {
        let mut out = Matrix::zeros(q.rows(), v.cols());
        Self::exact_into(&AttnInputs::new(q, k, v).with_mask(mask), &mut out, &mut AttnScratch::new());
        out
    }

    /// [`exact`](Self::exact) into a caller-provided output with recycled
    /// temporaries — the zero-allocation form.
    pub fn exact_into(inputs: &AttnInputs<'_>, out: &mut Matrix, scratch: &mut AttnScratch) {
        check_inputs("standard", true, inputs.q, inputs.k, inputs.v, inputs.mask);
        let p = inputs.q.cols() as f32;
        let mut scores = scratch.matrix(inputs.q.rows(), inputs.k.rows());
        matmul_nt_into(inputs.q, inputs.k, &mut scores);
        crate::tensor::scale_inplace(&mut scores, 1.0 / p.sqrt());
        masking::mask_score_columns(&mut scores, inputs.mask);
        softmax_rows(&mut scores);
        matmul_into(&scores, inputs.v, out);
        scratch.recycle(scores);
    }
}

impl AttentionMethod for Standard {
    fn name(&self) -> &'static str {
        "standard"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        _rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        Self::exact_into(inputs, out, scratch);
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // recompute *is* the exact streaming softmax here: a query costs
        // O(m·n·p) against the stored KV state — O(n·p) per decoded token
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_keys_give_row_mean_of_v() {
        // If all scores are equal, attention output is the mean of V rows.
        let n = 16;
        let q = Matrix::zeros(n, 4);
        let k = Matrix::from_fn(n, 4, |_, j| j as f32);
        let v = Matrix::from_fn(n, 4, |i, _| i as f32);
        let out = Standard::exact(&q, &k, &v, None);
        let mean = (0..n).map(|i| i as f32).sum::<f32>() / n as f32;
        for i in 0..n {
            assert!((out.get(i, 0) - mean).abs() < 1e-4);
        }
    }

    #[test]
    fn peaked_scores_select_one_row() {
        // Make query i align strongly with key i: output ≈ V row i.
        let n = 8;
        let p = 8;
        let big = 40.0;
        let q = Matrix::from_fn(n, p, |i, j| if i == j { big } else { 0.0 });
        let k = Matrix::from_fn(n, p, |i, j| if i == j { big } else { 0.0 });
        let v = Matrix::from_fn(n, p, |i, j| (i * 10 + j) as f32);
        let out = Standard::exact(&q, &k, &v, None);
        for i in 0..n {
            for j in 0..p {
                assert!((out.get(i, j) - v.get(i, j)).abs() < 1e-2, "row {i}");
            }
        }
    }

    #[test]
    fn masked_keys_do_not_contribute() {
        let n = 12;
        let p = 4;
        let q = Matrix::from_fn(n, p, |i, j| ((i + j) as f32).sin());
        let k = Matrix::from_fn(n, p, |i, j| ((i * j) as f32 * 0.1).cos());
        let mut v = Matrix::from_fn(n, p, |i, j| (i + j) as f32 * 0.1);
        let mut mask = vec![1.0f32; n];
        for i in 8..n {
            mask[i] = 0.0;
        }
        let base = Standard::exact(&q, &k, &v, Some(&mask));
        // corrupt padded V rows — output must not change
        for i in 8..n {
            for j in 0..p {
                v.set(i, j, 1e6);
            }
        }
        let after = Standard::exact(&q, &k, &v, Some(&mask));
        assert!(base.max_abs_diff(&after) < 1e-3);
    }

    #[test]
    fn rows_are_convex_combinations() {
        let n = 32;
        let q = Matrix::from_fn(n, 8, |i, j| ((i * 7 + j) as f32 * 0.2).sin());
        let k = Matrix::from_fn(n, 8, |i, j| ((i + j * 3) as f32 * 0.15).cos());
        let v = Matrix::from_fn(n, 8, |i, j| ((i * 13 + j * 5) % 9) as f32 - 4.0);
        let out = Standard::exact(&q, &k, &v, None);
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4);
        }
    }
}
