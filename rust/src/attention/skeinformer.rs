//! Skeinformer — Algorithm 1 of the paper, line by line, with the ablation
//! switches Table 1 studies (uniform sampling, row-norm variants, PSR).
//!
//! Complexity: O(n·d) time and space with d = O(log n) (§4.5).  The only
//! O(n²)-shaped object the exact method needs — the full score matrix —
//! never materialises: the pilot strip is (d, n) and the sampled strip is
//! (n, d).

use super::{check_inputs, masking, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::{
    col_norms, matmul, matmul_nt, row_geometric_means, row_norms, scale_inplace, softmax_rows,
    Matrix,
};

/// Row-normalization strategy (§4.2 + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowNorm {
    /// Adaptive row normalization: geometric-mean fill (Eq. 6) — the paper's method.
    Adaptive,
    /// Normalize by the selected-column sum only (Informer-style).
    Simple,
    /// No normalization: the plain importance-weighted AMM estimator.
    None,
}

/// Algorithm 1 with configurable components.
#[derive(Clone, Copy, Debug)]
pub struct Skeinformer {
    /// Sub-sample size `d` (pilot size == column-sample size).
    pub d: usize,
    /// Ablation: replace Eq.-5 importance weights with uniform.
    pub uniform_sampling: bool,
    /// Row-normalization strategy.
    pub row_norm: RowNorm,
    /// Pilot sampling reutilization (line 12).
    pub psr: bool,
}

impl Skeinformer {
    pub fn new(d: usize) -> Self {
        Self { d, uniform_sampling: false, row_norm: RowNorm::Adaptive, psr: true }
    }

    pub fn uniform_sampling(mut self) -> Self {
        self.uniform_sampling = true;
        self
    }

    pub fn row_norm(mut self, rn: RowNorm) -> Self {
        self.row_norm = rn;
        self
    }

    pub fn without_psr(mut self) -> Self {
        self.psr = false;
        self
    }

    /// Lines 1-3: uniform pilot sampling + `B_J = softmax(Q_J Kᵀ/√p)`.
    ///
    /// Returns `(pilot_idx, B_J)` with `B_J` shaped `(d, n)`; padded
    /// columns are zeroed per §4.4 so they can never be sampled.
    pub fn pilot(
        &self,
        q: &Matrix,
        k: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> (Vec<usize>, Matrix) {
        let n = q.rows();
        let d = self.d.min(n);
        let valid = masking::valid_indices(mask, n);
        let pilot_idx: Vec<usize> =
            (0..d).map(|_| valid[rng.below(valid.len())]).collect();
        let qj = q.gather_rows(&pilot_idx);
        let mut bj = matmul_nt(&qj, k); // (d, n)
        scale_inplace(&mut bj, 1.0 / (q.cols() as f32).sqrt());
        masking::mask_score_columns(&mut bj, mask);
        softmax_rows(&mut bj);
        masking::zero_masked_columns(&mut bj, mask);
        (pilot_idx, bj)
    }

    /// Equation (5): estimated sub-sampling probabilities
    /// `p̂_i ∝ (Σ_k b²_{j_k i})^{1/2} ‖V_(i)‖` (un-normalised weights —
    /// the sampler normalises internally).
    pub fn probabilities(bj: &Matrix, v: &Matrix, mask: Option<&[f32]>) -> Vec<f32> {
        let col = col_norms(bj);
        let vn = row_norms(v);
        let mut w: Vec<f32> = col.iter().zip(&vn).map(|(c, r)| c * r).collect();
        masking::mask_weights(&mut w, mask);
        if w.iter().all(|x| *x <= 0.0) {
            // degenerate pilot — fall back to uniform over valid positions
            for (i, wi) in w.iter_mut().enumerate() {
                *wi = mask.map_or(1.0, |m| m[i]);
            }
        }
        w
    }

    fn compute_impl(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let n = q.rows();
        let p = q.cols() as f32;
        let d = self.d.min(n);
        let m_valid = masking::valid_count(mask, n);

        // Lines 1-4: pilot sampling + probabilities.
        let (pilot_idx, bj) = self.pilot(q, k, mask, rng);
        let weights = if self.uniform_sampling {
            let mut w = vec![1.0f32; n];
            masking::mask_weights(&mut w, mask);
            w
        } else {
            Self::probabilities(&bj, v, mask)
        };

        // Line 5: importance sampling without replacement (Gumbel top-k).
        let sel_idx = rng.weighted_without_replacement(&weights, d);
        let d_eff = sel_idx.len();

        // Lines 6-7: gather K_{J'}, V_{J'}, compute A^{J'} = exp(Q K_{J'}ᵀ/√p).
        let k_sel = k.gather_rows(&sel_idx);
        let v_sel = v.gather_rows(&sel_idx);
        let mut a_sel = matmul_nt(q, &k_sel); // (n, d)
        scale_inplace(&mut a_sel, 1.0 / p.sqrt());
        // clip logits to ±30 before exp (f32 overflow guard — mirrors the
        // pallas kernel and jnp reference exactly)
        a_sel.data_mut().iter_mut().for_each(|x| *x = x.clamp(-30.0, 30.0).exp());
        let r_sel = matmul(&a_sel, &v_sel); // (n, p) — R_{J'}

        let mut r = match self.row_norm {
            RowNorm::Adaptive => {
                // Line 8: geometric-mean fill g.
                let g = row_geometric_means(&a_sel);
                // Line 9: d̂_i = Σ_k a_{ij'_k} + (m - d) g_i  (mask-aware count)
                let n_unsel = (m_valid - d_eff as f32).max(0.0);
                let row_sum: Vec<f32> = (0..n)
                    .map(|i| a_sel.row(i).iter().sum::<f32>() + n_unsel * g[i])
                    .collect();
                // Line 10: v = V_{(J')ᶜ}ᵀ 1
                let total = masking::masked_col_sums(v, mask);
                let sel_sum = crate::tensor::col_sums(&v_sel);
                let v_unsel: Vec<f32> =
                    total.iter().zip(&sel_sum).map(|(t, s)| t - s).collect();
                // Line 11: R = diag(d̂)⁻¹ (R_{J'} + g vᵀ)
                Matrix::from_fn(n, v.cols(), |i, j| {
                    (r_sel.get(i, j) + g[i] * v_unsel[j]) / row_sum[i].max(1e-30)
                })
            }
            RowNorm::Simple => {
                let mut out = r_sel;
                let inv: Vec<f32> = (0..n)
                    .map(|i| 1.0 / a_sel.row(i).iter().sum::<f32>().max(1e-30))
                    .collect();
                crate::tensor::scale_rows_inplace(&mut out, &inv);
                out
            }
            RowNorm::None => {
                // Plain AMM estimator of Prop. 1: rescale each sampled
                // column by 1/(d p̂_i), estimate the softmax row sum from
                // the same sample.
                let total_w: f32 = weights.iter().sum();
                let inv_dp: Vec<f32> = sel_idx
                    .iter()
                    .map(|&i| {
                        let p_i = (weights[i] / total_w).max(1e-30);
                        1.0 / (d_eff as f32 * p_i)
                    })
                    .collect();
                let mut out = Matrix::zeros(n, v.cols());
                for i in 0..n {
                    let arow = a_sel.row(i);
                    let mut est_row_sum = 0.0f32;
                    for (s, &w) in arow.iter().zip(&inv_dp) {
                        est_row_sum += s * w;
                    }
                    let inv = 1.0 / est_row_sum.max(1e-30);
                    let orow = out.row_mut(i);
                    for (jj, (&a, &w)) in arow.iter().zip(&inv_dp).enumerate() {
                        let coeff = a * w * inv;
                        for (o, &vv) in orow.iter_mut().zip(v_sel.row(jj)) {
                            *o += coeff * vv;
                        }
                    }
                }
                out
            }
        };

        // Line 12: pilot sampling reutilization — exact rows B_J V.
        if self.psr {
            let exact = matmul(&bj, v); // (d, p)
            for (row, &i) in pilot_idx.iter().enumerate() {
                r.set_row(i, exact.row(row));
            }
        }
        r
    }
}

impl AttentionMethod for Skeinformer {
    fn name(&self) -> &'static str {
        if self.uniform_sampling {
            "skein_uniform"
        } else if self.row_norm == RowNorm::None {
            "skein_no_norm"
        } else if self.row_norm == RowNorm::Simple {
            "skein_simple_norm"
        } else if !self.psr {
            "skein_no_psr"
        } else {
            "skeinformer"
        }
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        self.compute_impl(q, k, v, mask, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;

    fn peaked_qkv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        // correlated inputs -> non-uniform attention (the realistic regime)
        let mut rng = Rng::new(seed);
        let mut mk = |scale: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, scale);
            m
        };
        (mk(1.8), mk(1.8), mk(1.0))
    }

    #[test]
    fn full_sample_with_psr_is_near_exact() {
        // d == n: every column selected, pilot rows exact; the sampled part
        // still uses the geometric fill with weight (m-d)=0, so the result
        // should match the exact attention closely.
        let (q, k, v) = peaked_qkv(32, 8, 1);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(32);
        let out = skein.compute(&q, &k, &v, None, &mut Rng::new(2));
        assert!(
            out.max_abs_diff(&exact) < 1e-3,
            "diff {}",
            out.max_abs_diff(&exact)
        );
    }

    #[test]
    fn pilot_rows_match_exact_attention() {
        let (q, k, v) = peaked_qkv(64, 8, 3);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(16);
        // Re-derive the pilot set with the same RNG stream the compute uses.
        let mut rng_probe = Rng::new(7);
        let (pilot_idx, _) = skein.pilot(&q, &k, None, &mut rng_probe);
        let out = skein.compute(&q, &k, &v, None, &mut Rng::new(7));
        for &i in &pilot_idx {
            for j in 0..v.cols() {
                assert!(
                    (out.get(i, j) - exact.get(i, j)).abs() < 1e-4,
                    "pilot row {i} not exact"
                );
            }
        }
    }

    #[test]
    fn beats_vmean_on_structured_attention() {
        // The paper's regime (Figure 1, "pretrained"): token embeddings
        // share cluster structure, so important columns are shared across
        // rows and column sampling pays off.  (On i.i.d.-random peaked
        // inputs every row attends to its own private column — there the
        // rank-collapse premise doesn't hold and no column sketch helps.)
        use crate::attention::VMean;
        use crate::synth_qkv::{generate, QkvConfig};
        use crate::tensor::spectral_norm_diff;
        let mut gen_rng = Rng::new(5);
        let (q, k, v) = generate(&QkvConfig::pretrained(128, 16), &mut gen_rng);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(32);
        let mut err_sum = 0.0;
        for s in 0..6 {
            let out = skein.compute(&q, &k, &v, None, &mut Rng::new(100 + s));
            err_sum += spectral_norm_diff(&out, &exact);
        }
        let vm = VMean.compute(&q, &k, &v, None, &mut Rng::new(0));
        let vm_err = spectral_norm_diff(&vm, &exact);
        assert!(
            err_sum / 6.0 < vm_err,
            "skein {} vs vmean {}",
            err_sum / 6.0,
            vm_err
        );
    }

    #[test]
    fn never_samples_padded_columns() {
        let (q, k, v) = peaked_qkv(64, 8, 9);
        let mut mask = vec![1.0f32; 64];
        for m in mask.iter_mut().skip(40) {
            *m = 0.0;
        }
        let skein = Skeinformer::new(16);
        let (_, bj) = skein.pilot(&q, &k, Some(&mask), &mut Rng::new(4));
        let w = Skeinformer::probabilities(&bj, &v, Some(&mask));
        for (i, &wi) in w.iter().enumerate().skip(40) {
            assert_eq!(wi, 0.0, "padded index {i} has weight");
        }
    }

    #[test]
    fn padded_content_invariance() {
        let (q, k, v) = peaked_qkv(64, 8, 11);
        let mut mask = vec![1.0f32; 64];
        for m in mask.iter_mut().skip(48) {
            *m = 0.0;
        }
        let skein = Skeinformer::new(16);
        let a = skein.compute(&q, &k, &v, Some(&mask), &mut Rng::new(21));
        // corrupt padded rows of K and V
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for i in 48..64 {
            for j in 0..8 {
                k2.set(i, j, 1e3);
                v2.set(i, j, -1e3);
            }
        }
        let b = skein.compute(&q, &k2, &v2, Some(&mask), &mut Rng::new(21));
        for i in 0..48 {
            for j in 0..8 {
                assert!(
                    (a.get(i, j) - b.get(i, j)).abs() < 1e-3,
                    "row {i} leaked padding"
                );
            }
        }
    }

    #[test]
    fn ablations_produce_distinct_estimators() {
        let (q, k, v) = peaked_qkv(96, 8, 13);
        let base = Skeinformer::new(24);
        let out_full = base.compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_simple =
            base.row_norm(RowNorm::Simple).compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_none = base.row_norm(RowNorm::None).compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_nopsr = base.without_psr().compute(&q, &k, &v, None, &mut Rng::new(50));
        assert!(out_full.max_abs_diff(&out_simple) > 1e-6);
        assert!(out_full.max_abs_diff(&out_none) > 1e-6);
        assert!(out_full.max_abs_diff(&out_nopsr) > 1e-6);
    }

    #[test]
    fn adaptive_norm_rows_are_normalized_mixtures() {
        // With adaptive row norm (and no PSR, to see pure line-11 rows) the
        // output rows are convex-ish combinations of V rows plus the fill —
        // they must stay within a modest factor of V's range.
        let (q, k, v) = peaked_qkv(64, 8, 17);
        let out = Skeinformer::new(16)
            .without_psr()
            .compute(&q, &k, &v, None, &mut Rng::new(3));
        let vmax = v.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        for &x in out.data() {
            assert!(x.abs() <= vmax * 3.0, "unnormalized output {x}");
        }
    }
}
