//! Skeinformer — Algorithm 1 of the paper, line by line, with the ablation
//! switches Table 1 studies (uniform sampling, row-norm variants, PSR).
//!
//! Complexity: O(n·d) time and space with d = O(log n) (§4.5).  The only
//! O(n²)-shaped object the exact method needs — the full score matrix —
//! never materialises: the pilot strip is (d, n) and the sampled strip is
//! (n, d).
//!
//! Cross-shape (`m×p` decode queries against `n×p` cached keys) is
//! supported: pilot queries are then drawn uniformly from the `m` query
//! rows (queries carry no padding mask), while sub-sampling probabilities
//! and the mask still range over the `n` key positions.  With `m == n`
//! the draws reduce bit-for-bit to the classic square path.

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    RecomputeSession, SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{
    col_norms_into, col_sums_into, matmul_into, matmul_nt_into, row_geometric_means_into,
    row_norms_into, scale_inplace, scale_rows_inplace, softmax_rows, Matrix,
};

/// Row-normalization strategy (§4.2 + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowNorm {
    /// Adaptive row normalization: geometric-mean fill (Eq. 6) — the paper's method.
    Adaptive,
    /// Normalize by the selected-column sum only (Informer-style).
    Simple,
    /// No normalization: the plain importance-weighted AMM estimator.
    None,
}

/// Algorithm 1 with configurable components.
#[derive(Clone, Copy, Debug)]
pub struct Skeinformer {
    /// Sub-sample size `d` (pilot size == column-sample size).
    pub d: usize,
    /// Ablation: replace Eq.-5 importance weights with uniform.
    pub uniform_sampling: bool,
    /// Row-normalization strategy.
    pub row_norm: RowNorm,
    /// Pilot sampling reutilization (line 12).
    pub psr: bool,
}

impl Skeinformer {
    pub fn new(d: usize) -> Self {
        Self { d, uniform_sampling: false, row_norm: RowNorm::Adaptive, psr: true }
    }

    pub fn uniform_sampling(mut self) -> Self {
        self.uniform_sampling = true;
        self
    }

    pub fn row_norm(mut self, rn: RowNorm) -> Self {
        self.row_norm = rn;
        self
    }

    pub fn without_psr(mut self) -> Self {
        self.psr = false;
        self
    }

    /// Lines 1-3: uniform pilot sampling + `B_J = softmax(Q_J Kᵀ/√p)`.
    ///
    /// Returns `(pilot_idx, B_J)` with `B_J` shaped `(d, n)`; padded
    /// columns are zeroed per §4.4 so they can never be sampled.
    pub fn pilot(
        &self,
        q: &Matrix,
        k: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> (Vec<usize>, Matrix) {
        let mut pilot_idx = Vec::new();
        let pilot_d = self.d.min(q.rows());
        let mut bj = Matrix::zeros(pilot_d, k.rows());
        let mut scratch = AttnScratch::new();
        self.pilot_into(q, k, mask, rng, &mut pilot_idx, &mut bj, &mut scratch);
        (pilot_idx, bj)
    }

    /// [`pilot`](Self::pilot) into caller-provided storage (`pilot_idx`
    /// cleared and refilled; `bj` must be `(d.min(q.rows()), k.rows())`,
    /// fully overwritten).  Draws exactly the stream [`pilot`] draws.
    fn pilot_into(
        &self,
        q: &Matrix,
        k: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
        pilot_idx: &mut Vec<usize>,
        bj: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let m = q.rows();
        let pilot_d = self.d.min(m);
        pilot_idx.clear();
        if m == k.rows() {
            // square self-attention: pilot queries sampled over the valid
            // (un-padded) positions, exactly as in Algorithm 1
            let mut valid = scratch.idx_buf();
            masking::valid_indices_into(mask, m, &mut valid);
            pilot_idx.extend((0..pilot_d).map(|_| valid[rng.below(valid.len())]));
            scratch.recycle_idx(valid);
        } else {
            // cross-shape decode: queries carry no mask; sample uniformly
            pilot_idx.extend((0..pilot_d).map(|_| rng.below(m)));
        }
        let mut qj = scratch.matrix(pilot_d, q.cols());
        q.gather_rows_into(pilot_idx, &mut qj);
        matmul_nt_into(&qj, k, bj); // (d, n)
        scratch.recycle(qj);
        scale_inplace(bj, 1.0 / (q.cols() as f32).sqrt());
        masking::mask_score_columns(bj, mask);
        softmax_rows(bj);
        masking::zero_masked_columns(bj, mask);
    }

    /// Equation (5): estimated sub-sampling probabilities
    /// `p̂_i ∝ (Σ_k b²_{j_k i})^{1/2} ‖V_(i)‖` (un-normalised weights —
    /// the sampler normalises internally).
    pub fn probabilities(bj: &Matrix, v: &Matrix, mask: Option<&[f32]>) -> Vec<f32> {
        let mut w = vec![0.0f32; bj.cols()];
        let mut vn = vec![0.0f32; v.rows()];
        Self::probabilities_into(bj, v, mask, &mut w, &mut vn);
        w
    }

    /// [`probabilities`](Self::probabilities) into reused buffers: `w`
    /// (length `n`, the result) and `vn` (length `n`, row-norm workspace).
    fn probabilities_into(
        bj: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        w: &mut [f32],
        vn: &mut [f32],
    ) {
        col_norms_into(bj, w);
        row_norms_into(v, vn);
        for (wi, &r) in w.iter_mut().zip(vn.iter()) {
            *wi *= r;
        }
        masking::mask_weights(w, mask);
        if w.iter().all(|x| *x <= 0.0) {
            // degenerate pilot — fall back to uniform over valid positions
            for (i, wi) in w.iter_mut().enumerate() {
                *wi = mask.map_or(1.0, |m| m[i]);
            }
        }
    }

    fn compute_impl(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        let mask = inputs.mask;
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, mask);
        let m = q.rows(); // query rows
        let n = k.rows(); // key/value rows
        let p = q.cols() as f32;
        let pilot_d = self.d.min(m);
        let d = self.d.min(n);
        let m_valid = masking::valid_count(mask, n);

        // Lines 1-4: pilot sampling + probabilities.
        let mut pilot_idx = scratch.idx_buf();
        let mut bj = scratch.matrix(pilot_d, n);
        self.pilot_into(q, k, mask, rng, &mut pilot_idx, &mut bj, scratch);
        let mut weights = scratch.buf(n);
        if self.uniform_sampling {
            weights.iter_mut().for_each(|x| *x = 1.0);
            masking::mask_weights(&mut weights, mask);
        } else {
            let mut vn = scratch.buf(n);
            Self::probabilities_into(&bj, v, mask, &mut weights, &mut vn);
            scratch.recycle_buf(vn);
        }

        // Line 5: importance sampling without replacement (Gumbel top-k),
        // keys and indices drawn through recycled scratch — same stream
        // and selection as the allocating sampler, no per-call Vecs.
        let mut sel_idx = scratch.idx_buf();
        let mut keyed = scratch.pair_buf();
        rng.weighted_without_replacement_into(&weights, d, &mut keyed, &mut sel_idx);
        scratch.recycle_pair(keyed);
        let d_eff = sel_idx.len();

        // Lines 6-7: gather K_{J'}, V_{J'}, compute A^{J'} = exp(Q K_{J'}ᵀ/√p).
        let mut k_sel = scratch.matrix(d_eff, k.cols());
        let mut v_sel = scratch.matrix(d_eff, v.cols());
        k.gather_rows_into(&sel_idx, &mut k_sel);
        v.gather_rows_into(&sel_idx, &mut v_sel);
        let mut a_sel = scratch.matrix(m, d_eff); // (m, d)
        matmul_nt_into(q, &k_sel, &mut a_sel);
        scratch.recycle(k_sel);
        scale_inplace(&mut a_sel, 1.0 / p.sqrt());
        // clip logits to ±30 before exp (f32 overflow guard — mirrors the
        // pallas kernel and jnp reference exactly)
        a_sel.data_mut().iter_mut().for_each(|x| *x = x.clamp(-30.0, 30.0).exp());

        match self.row_norm {
            RowNorm::Adaptive => {
                let mut r_sel = scratch.matrix(m, v.cols()); // (m, p) — R_{J'}
                matmul_into(&a_sel, &v_sel, &mut r_sel);
                // Line 8: geometric-mean fill g.
                let mut g = scratch.buf(m);
                row_geometric_means_into(&a_sel, &mut g);
                // Line 9: d̂_i = Σ_k a_{ij'_k} + (m - d) g_i  (mask-aware count)
                let n_unsel = (m_valid - d_eff as f32).max(0.0);
                let mut row_sum = scratch.buf(m);
                for (i, rs) in row_sum.iter_mut().enumerate() {
                    *rs = a_sel.row(i).iter().sum::<f32>() + n_unsel * g[i];
                }
                // Line 10: v = V_{(J')ᶜ}ᵀ 1
                let mut v_unsel = scratch.buf(v.cols());
                masking::masked_col_sums_into(v, mask, &mut v_unsel);
                let mut sel_sum = scratch.buf(v.cols());
                col_sums_into(&v_sel, &mut sel_sum);
                for (t, &s) in v_unsel.iter_mut().zip(&sel_sum) {
                    *t -= s;
                }
                scratch.recycle_buf(sel_sum);
                // Line 11: R = diag(d̂)⁻¹ (R_{J'} + g vᵀ) — per-element
                // division, matching the allocating path bit-for-bit
                for i in 0..m {
                    let gi = g[i];
                    let denom = row_sum[i].max(1e-30);
                    for (o, (&r, &vu)) in
                        out.row_mut(i).iter_mut().zip(r_sel.row(i).iter().zip(&v_unsel))
                    {
                        *o = (r + gi * vu) / denom;
                    }
                }
                scratch.recycle_buf(v_unsel);
                scratch.recycle_buf(row_sum);
                scratch.recycle_buf(g);
                scratch.recycle(r_sel);
            }
            RowNorm::Simple => {
                matmul_into(&a_sel, &v_sel, out);
                let mut inv = scratch.buf(m);
                for (i, x) in inv.iter_mut().enumerate() {
                    *x = 1.0 / a_sel.row(i).iter().sum::<f32>().max(1e-30);
                }
                scale_rows_inplace(out, &inv);
                scratch.recycle_buf(inv);
            }
            RowNorm::None => {
                // Plain AMM estimator of Prop. 1: rescale each sampled
                // column by 1/(d p̂_i), estimate the softmax row sum from
                // the same sample.
                let total_w: f32 = weights.iter().sum();
                let mut inv_dp = scratch.buf(d_eff);
                for (x, &i) in inv_dp.iter_mut().zip(&sel_idx) {
                    let p_i = (weights[i] / total_w).max(1e-30);
                    *x = 1.0 / (d_eff as f32 * p_i);
                }
                out.data_mut().iter_mut().for_each(|x| *x = 0.0);
                for i in 0..m {
                    let arow = a_sel.row(i);
                    let mut est_row_sum = 0.0f32;
                    for (s, &w) in arow.iter().zip(inv_dp.iter()) {
                        est_row_sum += s * w;
                    }
                    let inv = 1.0 / est_row_sum.max(1e-30);
                    let orow = out.row_mut(i);
                    for (jj, (&a, &w)) in arow.iter().zip(inv_dp.iter()).enumerate() {
                        let coeff = a * w * inv;
                        for (o, &vv) in orow.iter_mut().zip(v_sel.row(jj)) {
                            *o += coeff * vv;
                        }
                    }
                }
                scratch.recycle_buf(inv_dp);
            }
        };
        scratch.recycle(a_sel);
        scratch.recycle(v_sel);
        scratch.recycle_buf(weights);

        // Line 12: pilot sampling reutilization — exact rows B_J V.
        if self.psr {
            let mut exact = scratch.matrix(pilot_d, v.cols()); // (d, p)
            matmul_into(&bj, v, &mut exact);
            for (row, &i) in pilot_idx.iter().enumerate() {
                out.set_row(i, exact.row(row));
            }
            scratch.recycle(exact);
        }
        scratch.recycle(bj);
        scratch.recycle_idx(pilot_idx);
        scratch.recycle_idx(sel_idx);
    }
}

impl AttentionMethod for Skeinformer {
    fn name(&self) -> &'static str {
        if self.uniform_sampling {
            "skein_uniform"
        } else if self.row_norm == RowNorm::None {
            "skein_no_norm"
        } else if self.row_norm == RowNorm::Simple {
            "skein_simple_norm"
        } else if !self.psr {
            "skein_no_psr"
        } else {
            "skeinformer"
        }
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        self.compute_impl(inputs, rng, out, scratch);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // re-pilot on the spec stride: each query runs Algorithm 1 over
        // the full KV state (O(n·d), the method's own complexity) with
        // the current epoch's seed
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;

    fn peaked_qkv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        // correlated inputs -> non-uniform attention (the realistic regime)
        let mut rng = Rng::new(seed);
        let mut mk = |scale: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, scale);
            m
        };
        (mk(1.8), mk(1.8), mk(1.0))
    }

    #[test]
    fn full_sample_with_psr_is_near_exact() {
        // d == n: every column selected, pilot rows exact; the sampled part
        // still uses the geometric fill with weight (m-d)=0, so the result
        // should match the exact attention closely.
        let (q, k, v) = peaked_qkv(32, 8, 1);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(32);
        let out = skein.compute(&q, &k, &v, None, &mut Rng::new(2));
        assert!(
            out.max_abs_diff(&exact) < 1e-3,
            "diff {}",
            out.max_abs_diff(&exact)
        );
    }

    #[test]
    fn pilot_rows_match_exact_attention() {
        let (q, k, v) = peaked_qkv(64, 8, 3);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(16);
        // Re-derive the pilot set with the same RNG stream the compute uses.
        let mut rng_probe = Rng::new(7);
        let (pilot_idx, _) = skein.pilot(&q, &k, None, &mut rng_probe);
        let out = skein.compute(&q, &k, &v, None, &mut Rng::new(7));
        for &i in &pilot_idx {
            for j in 0..v.cols() {
                assert!(
                    (out.get(i, j) - exact.get(i, j)).abs() < 1e-4,
                    "pilot row {i} not exact"
                );
            }
        }
    }

    #[test]
    fn beats_vmean_on_structured_attention() {
        // The paper's regime (Figure 1, "pretrained"): token embeddings
        // share cluster structure, so important columns are shared across
        // rows and column sampling pays off.  (On i.i.d.-random peaked
        // inputs every row attends to its own private column — there the
        // rank-collapse premise doesn't hold and no column sketch helps.)
        use crate::attention::VMean;
        use crate::synth_qkv::{generate, QkvConfig};
        use crate::tensor::spectral_norm_diff;
        let mut gen_rng = Rng::new(5);
        let (q, k, v) = generate(&QkvConfig::pretrained(128, 16), &mut gen_rng);
        let exact = Standard::exact(&q, &k, &v, None);
        let skein = Skeinformer::new(32);
        let mut err_sum = 0.0;
        for s in 0..6 {
            let out = skein.compute(&q, &k, &v, None, &mut Rng::new(100 + s));
            err_sum += spectral_norm_diff(&out, &exact);
        }
        let vm = VMean.compute(&q, &k, &v, None, &mut Rng::new(0));
        let vm_err = spectral_norm_diff(&vm, &exact);
        assert!(
            err_sum / 6.0 < vm_err,
            "skein {} vs vmean {}",
            err_sum / 6.0,
            vm_err
        );
    }

    #[test]
    fn never_samples_padded_columns() {
        let (q, k, v) = peaked_qkv(64, 8, 9);
        let mut mask = vec![1.0f32; 64];
        for m in mask.iter_mut().skip(40) {
            *m = 0.0;
        }
        let skein = Skeinformer::new(16);
        let (_, bj) = skein.pilot(&q, &k, Some(&mask), &mut Rng::new(4));
        let w = Skeinformer::probabilities(&bj, &v, Some(&mask));
        for (i, &wi) in w.iter().enumerate().skip(40) {
            assert_eq!(wi, 0.0, "padded index {i} has weight");
        }
    }

    #[test]
    fn padded_content_invariance() {
        let (q, k, v) = peaked_qkv(64, 8, 11);
        let mut mask = vec![1.0f32; 64];
        for m in mask.iter_mut().skip(48) {
            *m = 0.0;
        }
        let skein = Skeinformer::new(16);
        let a = skein.compute(&q, &k, &v, Some(&mask), &mut Rng::new(21));
        // corrupt padded rows of K and V
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for i in 48..64 {
            for j in 0..8 {
                k2.set(i, j, 1e3);
                v2.set(i, j, -1e3);
            }
        }
        let b = skein.compute(&q, &k2, &v2, Some(&mask), &mut Rng::new(21));
        for i in 0..48 {
            for j in 0..8 {
                assert!(
                    (a.get(i, j) - b.get(i, j)).abs() < 1e-3,
                    "row {i} leaked padding"
                );
            }
        }
    }

    #[test]
    fn ablations_produce_distinct_estimators() {
        let (q, k, v) = peaked_qkv(96, 8, 13);
        let base = Skeinformer::new(24);
        let out_full = base.compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_simple =
            base.row_norm(RowNorm::Simple).compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_none = base.row_norm(RowNorm::None).compute(&q, &k, &v, None, &mut Rng::new(50));
        let out_nopsr = base.without_psr().compute(&q, &k, &v, None, &mut Rng::new(50));
        assert!(out_full.max_abs_diff(&out_simple) > 1e-6);
        assert!(out_full.max_abs_diff(&out_none) > 1e-6);
        assert!(out_full.max_abs_diff(&out_nopsr) > 1e-6);
    }

    #[test]
    fn adaptive_norm_rows_are_normalized_mixtures() {
        // With adaptive row norm (and no PSR, to see pure line-11 rows) the
        // output rows are convex-ish combinations of V rows plus the fill —
        // they must stay within a modest factor of V's range.
        let (q, k, v) = peaked_qkv(64, 8, 17);
        let out = Skeinformer::new(16)
            .without_psr()
            .compute(&q, &k, &v, None, &mut Rng::new(3));
        let vmax = v.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        for &x in out.data() {
            assert!(x.abs() <= vmax * 3.0, "unnormalized output {x}");
        }
    }

    #[test]
    fn cross_shape_decode_queries_work() {
        // 4 decode queries against a 64-token KV cache: right shape,
        // finite, and reasonably close to the exact cross attention.
        let (q, k, v) = peaked_qkv(64, 8, 19);
        let q_dec = q.gather_rows(&[60, 61, 62, 63]);
        let skein = Skeinformer::new(48);
        let out = skein.compute(&q_dec, &k, &v, None, &mut Rng::new(4));
        assert_eq!(out.shape(), (4, 8));
        assert!(out.all_finite());
    }
}
