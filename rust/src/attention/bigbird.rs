//! Big Bird (Zaheer et al. 2020) — window + global + random block-sparse
//! attention, implemented with a true block-sparse gather (unlike the
//! dense-masked jnp form used in the small-n training graph) so the E8
//! scaling bench reflects its ~`5·n·d` FLOPs (Table 5's `5ndp`).

use super::{
    check_inputs, AttentionMethod, AttentionSession, AttnInputs, AttnScratch, RecomputeSession,
    SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct BigBird {
    /// Block size (paper default 64).
    pub block: usize,
    /// Window width in blocks (3 = self + left + right).
    pub window: usize,
    /// Number of global blocks (attend everywhere / attended by all).
    pub n_global: usize,
    /// Random blocks per query block (paper default 3).
    pub n_random: usize,
}

impl Default for BigBird {
    fn default() -> Self {
        Self { block: 16, window: 3, n_global: 1, n_random: 3 }
    }
}

impl BigBird {
    /// The set of key-block indices a query block attends to, written
    /// into a reused buffer (cleared first) — sorted and deduplicated,
    /// exactly the order the old `BTreeSet` form produced.
    fn attended_blocks_into(&self, qb: usize, nb: usize, rng: &mut Rng, out: &mut Vec<usize>) {
        out.clear();
        // window
        let half = self.window / 2;
        for off in 0..=half {
            out.push(qb.saturating_sub(off));
            out.push((qb + off).min(nb - 1));
        }
        // global columns
        out.extend(0..self.n_global.min(nb));
        // random
        out.extend((0..self.n_random).map(|_| rng.below(nb)));
        out.sort_unstable();
        out.dedup();
    }
}

impl AttentionMethod for BigBird {
    fn name(&self) -> &'static str {
        "bigbird"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        let mask = inputs.mask;
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, mask);
        let n = q.rows();
        let p = q.cols();
        let block = self.block.min(n).max(1);
        let nb = n.div_ceil(block);
        let scale = 1.0 / (p as f32).sqrt();
        out.data_mut().iter_mut().for_each(|x| *x = 0.0);

        // per-block key/block lists and per-row score strip, reused
        // across the whole grid instead of re-allocated per row/block
        // (scratch audit)
        let mut keys = scratch.idx_buf();
        let mut blocks = scratch.idx_buf();
        let mut scores = scratch.buf(0);

        for qb in 0..nb {
            let rows = qb * block..((qb + 1) * block).min(n);
            keys.clear();
            if qb < self.n_global {
                // global *rows* (first n_global blocks) attend to everything
                keys.extend(0..n);
            } else {
                // key side of global attention: global blocks already
                // included via attended_blocks_into (n_global blocks inserted).
                self.attended_blocks_into(qb, nb, rng, &mut blocks);
                for &b in blocks.iter() {
                    keys.extend(b * block..((b + 1) * block).min(n));
                }
            }
            for i in rows {
                let qi = q.row(i);
                // stable softmax over the gathered keys
                scores.clear();
                scores.extend(keys.iter().map(|&j| {
                    let masked = mask.is_some_and(|m| m[j] <= 0.0);
                    if masked {
                        f32::NEG_INFINITY
                    } else {
                        crate::tensor::dot(qi, k.row(j)) * scale
                    }
                }));
                let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for s in scores.iter_mut() {
                    *s = if max.is_finite() { (*s - max).exp() } else { 0.0 };
                    sum += *s;
                }
                let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
                let orow = out.row_mut(i);
                for (&j, &s) in keys.iter().zip(&scores) {
                    let w = s * inv;
                    if w != 0.0 {
                        crate::tensor::axpy(w, v.row(j), orow);
                    }
                }
            }
        }
        scratch.recycle_buf(scores);
        scratch.recycle_idx(blocks);
        scratch.recycle_idx(keys);
    }

    fn supports_cross_shape(&self) -> bool {
        // the window/global block pattern ties query position i to key
        // position i — a detached m-row query has no position
        false
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // square-only: session queries must supply all n query rows (the
        // block pattern needs every position); random blocks re-draw on
        // the epoch stride
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;

    fn qkv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = || {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            m
        };
        (mk(), mk(), mk())
    }

    #[test]
    fn covers_whole_sequence_when_blocks_exceed_n() {
        // tiny n: the pattern covers everything -> matches exact attention
        let (q, k, v) = qkv(16, 8, 1);
        let bb = BigBird { block: 16, window: 3, n_global: 1, n_random: 1 };
        let out = bb.compute(&q, &k, &v, None, &mut Rng::new(2));
        let exact = Standard::exact(&q, &k, &v, None);
        assert!(out.max_abs_diff(&exact) < 1e-3);
    }

    #[test]
    fn global_rows_see_distant_values() {
        let (q, k, mut v) = qkv(128, 8, 3);
        let bb = BigBird::default();
        let base = bb.compute(&q, &k, &v, None, &mut Rng::new(5));
        for j in 0..8 {
            v.set(127, j, v.get(127, j) + 50.0);
        }
        let after = bb.compute(&q, &k, &v, None, &mut Rng::new(5));
        // row 0 is global -> must see the change at position 127
        let delta: f32 = (0..8).map(|j| (after.get(0, j) - base.get(0, j)).abs()).sum();
        assert!(delta > 1e-3, "global row blind to distant value");
    }

    #[test]
    fn window_rows_ignore_far_blocks_mostly() {
        // a middle row with no random hit on the far block should be
        // unaffected by changes there in *most* seeds; verify at least the
        // window part dominates by checking rows stay finite and bounded.
        let (q, k, v) = qkv(128, 8, 7);
        let out = BigBird::default().compute(&q, &k, &v, None, &mut Rng::new(9));
        assert!(out.all_finite());
    }

    #[test]
    fn rows_are_convex_combinations() {
        let (q, k, v) = qkv(96, 8, 11);
        let out = BigBird::default().compute(&q, &k, &v, None, &mut Rng::new(1));
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4);
        }
    }

    #[test]
    fn masked_keys_excluded() {
        let (q, k, v) = qkv(64, 8, 13);
        let mut mask = vec![1.0f32; 64];
        for m in mask.iter_mut().skip(48) {
            *m = 0.0;
        }
        let bb = BigBird::default();
        let a = bb.compute(&q, &k, &v, Some(&mask), &mut Rng::new(3));
        let mut v2 = v.clone();
        for i in 48..64 {
            for j in 0..8 {
                v2.set(i, j, 1e5);
            }
        }
        let b = bb.compute(&q, &k, &v2, Some(&mask), &mut Rng::new(3));
        assert!(a.max_abs_diff(&b) < 1e-2);
    }
}
