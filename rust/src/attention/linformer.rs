//! Linformer (Wang et al. 2020) — JL-sketch attention, in both forms the
//! paper analyses (§3.3):
//!
//! * [`Linformer`] — the *reduced* form the published model ships:
//!   `softmax(Q (SᵀK)ᵀ / √p) (SᵀV)` — sketch first, softmax after, which
//!   "deviates from the usual sketching form for efficiency".
//! * [`LinformerUnreducedJlt`] — the true sketching form `D⁻¹ A S Sᵀ V`
//!   (Table 1's "w/ unreduced JLT"): compute the full attention, then
//!   sketch V.  O(n²) — it exists to *measure* what the reduction costs.

use super::{check_inputs, masking, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_nt, matmul_tn, scale_inplace, softmax_rows, Matrix};

/// Draw an (n, d) Gaussian sketch `S` with `E[S Sᵀ] = I` (entries
/// N(0, 1/d)); masked rows are zeroed so padding carries no mass.
fn gaussian_sketch(n: usize, d: usize, mask: Option<&[f32]>, rng: &mut Rng) -> Matrix {
    let std = 1.0 / (d as f32).sqrt();
    let mut s = Matrix::zeros(n, d);
    for i in 0..n {
        let keep = mask.map_or(1.0, |m| m[i]);
        if keep > 0.0 {
            for x in s.row_mut(i) {
                *x = rng.normal() * std;
            }
        }
    }
    s
}

#[derive(Clone, Copy, Debug)]
pub struct Linformer {
    pub d: usize,
}

impl Linformer {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl AttentionMethod for Linformer {
    fn name(&self) -> &'static str {
        "linformer"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let p = q.cols() as f32;
        let s = gaussian_sketch(k.rows(), self.d, mask, rng);
        let k_proj = matmul_tn(&s, k); // (d, p)
        let v_proj = matmul_tn(&s, v); // (d, p)
        let mut scores = matmul_nt(q, &k_proj); // (n, d)
        scale_inplace(&mut scores, 1.0 / p.sqrt());
        softmax_rows(&mut scores);
        matmul(&scores, &v_proj)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LinformerUnreducedJlt {
    pub d: usize,
}

impl LinformerUnreducedJlt {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl AttentionMethod for LinformerUnreducedJlt {
    fn name(&self) -> &'static str {
        "linformer_jlt"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let p = q.cols() as f32;
        // full attention score matrix B = D⁻¹A (this form is O(n²) by design)
        let mut b = matmul_nt(q, k);
        scale_inplace(&mut b, 1.0 / p.sqrt());
        masking::mask_score_columns(&mut b, mask);
        softmax_rows(&mut b);
        let s = gaussian_sketch(k.rows(), self.d, mask, rng);
        let bs = matmul(&b, &s); // (n, d)
        let sv = matmul_tn(&s, v); // (d, p)
        matmul(&bs, &sv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::spectral_norm_diff;

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn sketch_is_approximately_isometric() {
        // E[S Sᵀ] = I  ⇒  ‖Sᵀx‖ ≈ ‖x‖ for fixed x, averaged over draws.
        let mut rng = Rng::new(1);
        let n = 128;
        let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.1).sin()).collect();
        let xn: f32 = x.iter().map(|a| a * a).sum::<f32>();
        let mut est = 0.0f32;
        let trials = 64;
        for _ in 0..trials {
            let s = gaussian_sketch(n, 64, None, &mut rng);
            let xm = Matrix::from_vec(1, n, x.clone());
            let proj = matmul(&xm, &s);
            est += proj.data().iter().map(|a| a * a).sum::<f32>();
        }
        est /= trials as f32;
        assert!((est / xn - 1.0).abs() < 0.15, "ratio {}", est / xn);
    }

    #[test]
    fn unreduced_jlt_converges_with_d() {
        let (q, k, v) = qkv(96, 8, 2, 1.5);
        let exact = Standard::exact(&q, &k, &v, None);
        let mean_err = |d: usize| {
            let jl = LinformerUnreducedJlt::new(d);
            (0..8)
                .map(|s| {
                    spectral_norm_diff(
                        &jl.compute(&q, &k, &v, None, &mut Rng::new(100 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 8.0
        };
        let e8 = mean_err(8);
        let e64 = mean_err(64);
        assert!(e64 < e8, "err d=8 {e8} vs d=64 {e64}");
    }

    #[test]
    fn unreduced_beats_reduced_on_peaked_inputs() {
        // The paper's observation: the reduced form trades accuracy.
        let (q, k, v) = qkv(96, 8, 3, 2.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let avg = |f: &dyn AttentionMethod| {
            (0..10)
                .map(|s| {
                    spectral_norm_diff(
                        &f.compute(&q, &k, &v, None, &mut Rng::new(200 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 10.0
        };
        let red = avg(&Linformer::new(24));
        let unred = avg(&LinformerUnreducedJlt::new(24));
        assert!(unred < red, "unreduced {unred} vs reduced {red}");
    }

    #[test]
    fn masked_rows_carry_no_sketch_mass() {
        let mut rng = Rng::new(4);
        let mask = [1.0, 1.0, 0.0, 0.0];
        let s = gaussian_sketch(4, 8, Some(&mask), &mut rng);
        assert!(s.row(2).iter().all(|x| *x == 0.0));
        assert!(s.row(3).iter().all(|x| *x == 0.0));
        assert!(s.row(0).iter().any(|x| *x != 0.0));
    }
}
