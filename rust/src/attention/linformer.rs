//! Linformer (Wang et al. 2020) — JL-sketch attention, in both forms the
//! paper analyses (§3.3):
//!
//! * [`Linformer`] — the *reduced* form the published model ships:
//!   `softmax(Q (SᵀK)ᵀ / √p) (SᵀV)` — sketch first, softmax after, which
//!   "deviates from the usual sketching form for efficiency".
//! * [`LinformerUnreducedJlt`] — the true sketching form `D⁻¹ A S Sᵀ V`
//!   (Table 1's "w/ unreduced JLT"): compute the full attention, then
//!   sketch V.  O(n²) — it exists to *measure* what the reduction costs.

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    LinformerSession, RecomputeSession, SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{
    matmul_into, matmul_nt_into, matmul_tn_into, scale_inplace, softmax_rows, Matrix,
};

/// Draw an (n, d) Gaussian sketch `S` with `E[S Sᵀ] = I` (entries
/// N(0, 1/d)) into a zero-filled scratch matrix; masked rows stay zero so
/// padding carries no mass.
fn gaussian_sketch_into(s: &mut Matrix, mask: Option<&[f32]>, rng: &mut Rng) {
    let d = s.cols();
    let std = 1.0 / (d as f32).sqrt();
    for i in 0..s.rows() {
        let keep = mask.map_or(1.0, |m| m[i]);
        if keep > 0.0 {
            for x in s.row_mut(i) {
                *x = rng.normal() * std;
            }
        }
    }
}

#[cfg_attr(not(test), allow(dead_code))]
fn gaussian_sketch(n: usize, d: usize, mask: Option<&[f32]>, rng: &mut Rng) -> Matrix {
    let mut s = Matrix::zeros(n, d);
    gaussian_sketch_into(&mut s, mask, rng);
    s
}

#[derive(Clone, Copy, Debug)]
pub struct Linformer {
    pub d: usize,
}

impl Linformer {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl AttentionMethod for Linformer {
    fn name(&self) -> &'static str {
        "linformer"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, inputs.mask);
        let p = q.cols() as f32;
        let mut s = scratch.matrix(k.rows(), self.d);
        gaussian_sketch_into(&mut s, inputs.mask, rng);
        let mut k_proj = scratch.matrix(self.d, k.cols());
        let mut v_proj = scratch.matrix(self.d, v.cols());
        matmul_tn_into(&s, k, &mut k_proj); // (d, p)
        matmul_tn_into(&s, v, &mut v_proj); // (d, p)
        scratch.recycle(s);
        let mut scores = scratch.matrix(q.rows(), self.d); // (m, d)
        matmul_nt_into(q, &k_proj, &mut scores);
        scale_inplace(&mut scores, 1.0 / p.sqrt());
        softmax_rows(&mut scores);
        matmul_into(&scores, &v_proj, out);
        scratch.recycle(scores);
        scratch.recycle(v_proj);
        scratch.recycle(k_proj);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn session_is_exact_incremental(&self) -> bool {
        true // incremental SᵀK/SᵀV projections: O(d·p) state, no stored K/V
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // exact incremental projections: O(d·p) per appended token
        Box::new(LinformerSession::new(self.d, spec))
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LinformerUnreducedJlt {
    pub d: usize,
}

impl LinformerUnreducedJlt {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl AttentionMethod for LinformerUnreducedJlt {
    fn name(&self) -> &'static str {
        "linformer_jlt"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, inputs.mask);
        let p = q.cols() as f32;
        // full attention score matrix B = D⁻¹A (this form is O(n²) by design)
        let mut b = scratch.matrix(q.rows(), k.rows());
        matmul_nt_into(q, k, &mut b);
        scale_inplace(&mut b, 1.0 / p.sqrt());
        masking::mask_score_columns(&mut b, inputs.mask);
        softmax_rows(&mut b);
        let mut s = scratch.matrix(k.rows(), self.d);
        gaussian_sketch_into(&mut s, inputs.mask, rng);
        let mut bs = scratch.matrix(q.rows(), self.d); // (m, d)
        matmul_into(&b, &s, &mut bs);
        scratch.recycle(b);
        let mut sv = scratch.matrix(self.d, v.cols()); // (d, p)
        matmul_tn_into(&s, v, &mut sv);
        scratch.recycle(s);
        matmul_into(&bs, &sv, out);
        scratch.recycle(sv);
        scratch.recycle(bs);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // O(n²) by design, so the session recomputes with the epoch seed
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::{matmul, spectral_norm_diff};

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn sketch_is_approximately_isometric() {
        // E[S Sᵀ] = I  ⇒  ‖Sᵀx‖ ≈ ‖x‖ for fixed x, averaged over draws.
        let mut rng = Rng::new(1);
        let n = 128;
        let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.1).sin()).collect();
        let xn: f32 = x.iter().map(|a| a * a).sum::<f32>();
        let mut est = 0.0f32;
        let trials = 64;
        for _ in 0..trials {
            let s = gaussian_sketch(n, 64, None, &mut rng);
            let xm = Matrix::from_vec(1, n, x.clone());
            let proj = matmul(&xm, &s);
            est += proj.data().iter().map(|a| a * a).sum::<f32>();
        }
        est /= trials as f32;
        assert!((est / xn - 1.0).abs() < 0.15, "ratio {}", est / xn);
    }

    #[test]
    fn unreduced_jlt_converges_with_d() {
        let (q, k, v) = qkv(96, 8, 2, 1.5);
        let exact = Standard::exact(&q, &k, &v, None);
        let mean_err = |d: usize| {
            let jl = LinformerUnreducedJlt::new(d);
            (0..8)
                .map(|s| {
                    spectral_norm_diff(
                        &jl.compute(&q, &k, &v, None, &mut Rng::new(100 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 8.0
        };
        let e8 = mean_err(8);
        let e64 = mean_err(64);
        assert!(e64 < e8, "err d=8 {e8} vs d=64 {e64}");
    }

    #[test]
    fn unreduced_beats_reduced_on_peaked_inputs() {
        // The paper's observation: the reduced form trades accuracy.
        let (q, k, v) = qkv(96, 8, 3, 2.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let avg = |f: &dyn AttentionMethod| {
            (0..10)
                .map(|s| {
                    spectral_norm_diff(
                        &f.compute(&q, &k, &v, None, &mut Rng::new(200 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 10.0
        };
        let red = avg(&Linformer::new(24));
        let unred = avg(&LinformerUnreducedJlt::new(24));
        assert!(unred < red, "unreduced {unred} vs reduced {red}");
    }

    #[test]
    fn masked_rows_carry_no_sketch_mass() {
        let mut rng = Rng::new(4);
        let mask = [1.0, 1.0, 0.0, 0.0];
        let s = gaussian_sketch(4, 8, Some(&mask), &mut rng);
        assert!(s.row(2).iter().all(|x| *x == 0.0));
        assert!(s.row(3).iter().all(|x| *x == 0.0));
        assert!(s.row(0).iter().any(|x| *x != 0.0));
    }
}
