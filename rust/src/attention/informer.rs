//! Informer (Zhou et al. 2020) — ProbSparse attention, as analysed in §3.3:
//! a deterministic variant of row sub-sampling sketching where the top-u
//! queries under the sparsity measurement `M_i` attend exactly and the
//! remaining rows fall back to the mean of V.
//!
//! The sparsity measurement is estimated from a uniformly-sampled subset of
//! keys (max-minus-mean surrogate, the published implementation's choice).
//! `with_padding_mask()` is the paper's §4.4 extension that makes Informer
//! usable on padded NLP batches (Table 1's "Informer w/ padding mask").

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    RecomputeSession, SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{matmul_into, matmul_nt_into, scale_inplace, softmax_rows, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct Informer {
    /// Number of exactly-attended queries (the paper's feature budget).
    pub u: usize,
    /// §4.4 padding-mask handling.
    pub padding_mask: bool,
}

impl Informer {
    pub fn new(u: usize) -> Self {
        Self { u, padding_mask: false }
    }

    pub fn with_padding_mask(mut self) -> Self {
        self.padding_mask = true;
        self
    }

    /// Estimate the sparsity measurement for every query from a sampled
    /// key subset, into `out` (length `q.rows()`, fully overwritten).
    /// Query rows that are themselves padded (square case only — in
    /// cross shape queries carry no mask) score `-inf`.
    fn sparsity_scores_into(
        &self,
        q: &Matrix,
        k: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
        out: &mut [f32],
        scratch: &mut AttnScratch,
    ) {
        let m = q.rows();
        let n = k.rows();
        let p = q.cols() as f32;
        let s = self.u.min(n);
        let mut valid = scratch.idx_buf();
        masking::valid_indices_into(mask, n, &mut valid);
        let mut samp = scratch.idx_buf();
        samp.extend((0..s).map(|_| valid[rng.below(valid.len())]));
        scratch.recycle_idx(valid);
        let mut k_samp = scratch.matrix(s, k.cols());
        k.gather_rows_into(&samp, &mut k_samp);
        scratch.recycle_idx(samp);
        let mut scores = scratch.matrix(m, s); // (m, s)
        matmul_nt_into(q, &k_samp, &mut scores);
        scratch.recycle(k_samp);
        scale_inplace(&mut scores, 1.0 / p.sqrt());
        // a query row is maskable only in the square case, where query
        // position i is key position i
        let query_mask = if m == n { mask } else { None };
        for (i, o) in out.iter_mut().enumerate() {
            if let Some(mm) = query_mask {
                if mm[i] <= 0.0 {
                    *o = f32::NEG_INFINITY;
                    continue;
                }
            }
            let row = scores.row(i);
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mean = row.iter().sum::<f32>() / row.len() as f32;
            *o = max - mean;
        }
        scratch.recycle(scores);
    }
}

impl AttentionMethod for Informer {
    fn name(&self) -> &'static str {
        if self.padding_mask {
            "informer_mask"
        } else {
            "informer"
        }
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, inputs.mask);
        let m_rows = q.rows();
        let n = k.rows();
        let p = q.cols() as f32;
        let u = self.u.min(m_rows);
        let eff_mask = if self.padding_mask { inputs.mask } else { None };

        let mut sparsity = scratch.buf(m_rows);
        self.sparsity_scores_into(q, k, eff_mask, rng, &mut sparsity, scratch);
        // top-u queries by sparsity measurement
        let mut idx = scratch.idx_buf();
        idx.extend(0..m_rows);
        idx.select_nth_unstable_by(u.saturating_sub(1).min(m_rows - 1), |&a, &b| {
            sparsity[b].partial_cmp(&sparsity[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(u);
        scratch.recycle_buf(sparsity);

        // exact attention for the top queries
        let mut q_top = scratch.matrix(u, q.cols());
        q.gather_rows_into(&idx, &mut q_top);
        let mut scores = scratch.matrix(u, n); // (u, n)
        matmul_nt_into(&q_top, k, &mut scores);
        scratch.recycle(q_top);
        scale_inplace(&mut scores, 1.0 / p.sqrt());
        masking::mask_score_columns(&mut scores, eff_mask);
        softmax_rows(&mut scores);
        let mut exact = scratch.matrix(u, v.cols()); // (u, p)
        matmul_into(&scores, v, &mut exact);
        scratch.recycle(scores);

        // remaining rows: mean of V (Informer's non-causal row fill)
        let m = masking::valid_count(eff_mask, n);
        let mut sums = scratch.buf(v.cols());
        masking::masked_col_sums_into(v, eff_mask, &mut sums);
        for i in 0..m_rows {
            for (o, &s) in out.row_mut(i).iter_mut().zip(&sums) {
                *o = s / m;
            }
        }
        scratch.recycle_buf(sums);
        for (row, &i) in idx.iter().enumerate() {
            out.set_row(i, exact.row(row));
        }
        scratch.recycle(exact);
        scratch.recycle_idx(idx);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // ProbSparse re-selects its top queries per query batch, so the
        // session recomputes over the full state with the epoch seed
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn selected_rows_are_exact_others_are_mean() {
        let (q, k, v) = qkv(48, 8, 1, 2.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let out = Informer::new(12).compute(&q, &k, &v, None, &mut Rng::new(2));
        let mean: Vec<f32> = (0..8)
            .map(|j| (0..48).map(|i| v.get(i, j)).sum::<f32>() / 48.0)
            .collect();
        let mut n_exact = 0;
        let mut n_mean = 0;
        for i in 0..48 {
            let is_exact =
                (0..8).all(|j| (out.get(i, j) - exact.get(i, j)).abs() < 1e-3);
            let is_mean = (0..8).all(|j| (out.get(i, j) - mean[j]).abs() < 1e-5);
            assert!(is_exact || is_mean, "row {i} neither exact nor mean");
            if is_mean {
                n_mean += 1;
            } else {
                n_exact += 1;
            }
        }
        assert!(n_exact >= 12 - 2, "too few exact rows: {n_exact}");
        assert!(n_mean > 0);
    }

    #[test]
    fn u_equals_n_recovers_standard() {
        let (q, k, v) = qkv(24, 8, 3, 1.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let out = Informer::new(24).compute(&q, &k, &v, None, &mut Rng::new(4));
        assert!(out.max_abs_diff(&exact) < 1e-3);
    }

    #[test]
    fn masked_variant_ignores_padding_content() {
        let (q, k, v) = qkv(40, 8, 5, 1.0);
        let mut mask = vec![1.0f32; 40];
        for m in mask.iter_mut().skip(30) {
            *m = 0.0;
        }
        let inf = Informer::new(10).with_padding_mask();
        let a = inf.compute(&q, &k, &v, Some(&mask), &mut Rng::new(6));
        let mut v2 = v.clone();
        let mut k2 = k.clone();
        for i in 30..40 {
            for j in 0..8 {
                v2.set(i, j, 1e4);
                k2.set(i, j, 1e4);
            }
        }
        let b = inf.compute(&q, &k2, &v2, Some(&mask), &mut Rng::new(6));
        for i in 0..30 {
            for j in 0..8 {
                assert!((a.get(i, j) - b.get(i, j)).abs() < 1e-2, "row {i} leaked");
            }
        }
    }

    #[test]
    fn sparsity_selects_peaked_queries() {
        // Construct one query with a huge aligned key -> extreme sparsity;
        // it must be among the selected (exact) rows.
        let n = 32;
        let p = 8;
        let mut q = Matrix::zeros(n, p);
        let mut k = Matrix::zeros(n, p);
        let mut rng = Rng::new(7);
        q.data_mut().iter_mut().for_each(|x| *x = rng.normal() * 0.1);
        k.data_mut().iter_mut().for_each(|x| *x = rng.normal() * 0.1);
        for j in 0..p {
            q.set(5, j, 10.0);
            k.set(9, j, 10.0);
        }
        let v = Matrix::from_fn(n, p, |i, j| ((i * p + j) as f32 * 0.05).sin());
        let exact = Standard::exact(&q, &k, &v, None);
        let out = Informer::new(4).compute(&q, &k, &v, None, &mut Rng::new(8));
        for j in 0..p {
            assert!(
                (out.get(5, j) - exact.get(5, j)).abs() < 1e-3,
                "peaked query row not selected"
            );
        }
    }
}
