//! Stateful streaming attention sessions — the autoregressive-decode
//! formulation of every registry method.
//!
//! A session is opened from a method
//! ([`AttentionMethod::begin_session`](super::AttentionMethod::begin_session)),
//! fed one `(k_row, v_row)` token at a time with [`AttentionSession::append`],
//! and queried with any number of `m×p` query rows against everything
//! appended so far.  This is the serving shape the batched `compute` call
//! cannot express: the KV state persists across calls, so a decode step
//! costs one append plus one query instead of a from-scratch recompute
//! over re-uploaded tensors.
//!
//! **Exactness contract.**
//!
//! * *Exact incremental sessions* — [`VMeanSession`] (running masked
//!   column sums, O(p) per token) and [`LinformerSession`] (the sketch
//!   projections `SᵀK`, `SᵀV` maintained one rank-1 update per token,
//!   O(d·p)) — produce **bitwise** the output a full recompute at the
//!   session seed would: the incremental accumulation performs the same
//!   float additions in the same order as the batch kernels.
//! * *Recompute sessions* ([`RecomputeSession`], the default for every
//!   other method) — store the appended K/V and serve each query by
//!   running the method over the full state.  For linear-time methods
//!   (Skeinformer et al.) that is O(n·d) work per query — the same
//!   asymptotics as a true incremental step — and for `Standard` it is
//!   the exact O(n·p) streaming softmax.
//!
//! **Re-pilot stride.** Approximating methods refresh their sampling
//! randomness every [`SessionSpec::repilot_stride`] appended tokens: a
//! query at length `n` computes with seed [`session_seed`]`(spec.seed,`
//! [`session_epoch`]`(n, stride))`.  Within an epoch the pilot draw is
//! frozen (queries are reproducible and comparable); at stride 1 every
//! token re-pilots, so a session query is bit-identical to a full
//! recompute at the same derived seed.  Exact sessions ignore the stride
//! (they have no sampling randomness to refresh).
//!
//! **Bounded state.** Unbounded streams cannot keep O(n) KV state
//! forever; [`BoundedSession`] caps a session at a sliding window of the
//! last `window` tokens with deterministic oldest-first eviction.  Its
//! epoch is derived from the *total* appended count — not the window
//! length, which plateaus — so re-pilot seeds keep advancing after
//! eviction starts, exactly as an unbounded session's would.

use super::{AttentionMethod, AttnInputs, AttnScratch};
use crate::rng::Rng;
use crate::tensor::{matmul_into, matmul_nt_into, scale_inplace, softmax_rows, Matrix};

/// Configuration for a streaming session.
#[derive(Clone, Copy, Debug)]
pub struct SessionSpec {
    /// Per-head feature dimension `p` of the K/V rows (and query rows).
    pub head_dim: usize,
    /// Base seed; query-time randomness derives via [`session_seed`].
    pub seed: u64,
    /// Re-pilot every this many appended tokens (clamped to ≥ 1).
    /// Ignored by exact sessions.
    pub repilot_stride: usize,
    /// Expected token count — a reservation hint, not a cap.
    pub capacity_hint: usize,
}

impl SessionSpec {
    pub fn new(head_dim: usize) -> Self {
        Self { head_dim, seed: 0, repilot_stride: 1, capacity_hint: 0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_repilot_stride(mut self, stride: usize) -> Self {
        self.repilot_stride = stride;
        self
    }

    pub fn with_capacity_hint(mut self, tokens: usize) -> Self {
        self.capacity_hint = tokens;
        self
    }

    /// The effective stride (`repilot_stride` clamped to ≥ 1).
    pub fn stride(&self) -> usize {
        self.repilot_stride.max(1)
    }
}

/// The re-pilot epoch a session of length `appended` is in.
pub fn session_epoch(appended: usize, stride: usize) -> u64 {
    (appended / stride.max(1)) as u64
}

/// The seed a session query computes with at a given epoch — a
/// [`mix`](crate::rng::mix) of the spec seed and the epoch, so epochs get
/// decorrelated streams and tests can reproduce any query exactly.
pub fn session_seed(base: u64, epoch: u64) -> u64 {
    crate::rng::mix(base, epoch)
}

/// A stateful attention stream: appended `(k, v)` token state plus the
/// method-specific incremental machinery.  See the [module docs](self)
/// for the exactness and re-pilot contract.
pub trait AttentionSession: Send {
    /// Per-head feature dimension `p`.
    fn head_dim(&self) -> usize;

    /// Tokens appended so far.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one token's key and value rows (each length
    /// [`head_dim`](Self::head_dim)).
    fn append(&mut self, k_row: &[f32], v_row: &[f32]);

    /// Compute attention of `q` (`m × p`) against every appended token,
    /// into `out` (`m × p`, fully overwritten).
    ///
    /// # Panics
    ///
    /// Panics if the session is empty, `q.cols() != head_dim`, or the
    /// underlying method rejects cross-shape queries and `m != len`.
    fn query_into(&mut self, q: &Matrix, out: &mut Matrix, scratch: &mut AttnScratch);

    /// Allocating convenience over [`query_into`](Self::query_into).
    fn query(&mut self, q: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(q.rows(), self.head_dim());
        self.query_into(q, &mut out, &mut AttnScratch::new());
        out
    }
}

// ---------------------------------------------------------------------------
// Recompute session (the generic fallback)
// ---------------------------------------------------------------------------

/// The generic session: append into growing K/V buffers, serve queries by
/// running the wrapped method over the full state with the epoch seed.
/// Exact for `Standard` (streaming softmax); for approximating methods
/// this *is* the re-pilot: sampling randomness refreshes every
/// [`SessionSpec::repilot_stride`] tokens.
pub struct RecomputeSession<M> {
    method: M,
    spec: SessionSpec,
    k_data: Vec<f32>,
    v_data: Vec<f32>,
    len: usize,
}

impl<M: AttentionMethod + Send + 'static> RecomputeSession<M> {
    pub fn new(method: M, spec: SessionSpec) -> Self {
        let reserve = spec.capacity_hint * spec.head_dim;
        Self {
            method,
            spec,
            k_data: Vec::with_capacity(reserve),
            v_data: Vec::with_capacity(reserve),
            len: 0,
        }
    }

    pub fn boxed(method: M, spec: SessionSpec) -> Box<dyn AttentionSession> {
        Box::new(Self::new(method, spec))
    }
}

impl<M: AttentionMethod + Send + 'static> AttentionSession for RecomputeSession<M> {
    fn head_dim(&self) -> usize {
        self.spec.head_dim
    }

    fn len(&self) -> usize {
        self.len
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        let p = self.spec.head_dim;
        assert_eq!(k_row.len(), p, "k_row length != head_dim");
        assert_eq!(v_row.len(), p, "v_row length != head_dim");
        self.k_data.extend_from_slice(k_row);
        self.v_data.extend_from_slice(v_row);
        self.len += 1;
    }

    fn query_into(&mut self, q: &Matrix, out: &mut Matrix, scratch: &mut AttnScratch) {
        assert!(self.len > 0, "query on an empty session");
        assert_eq!(q.cols(), self.spec.head_dim, "query head_dim mismatch");
        let p = self.spec.head_dim;
        // wrap the owned buffers as matrices without copying, and put
        // them back afterwards
        let k = Matrix::from_vec(self.len, p, std::mem::take(&mut self.k_data));
        let v = Matrix::from_vec(self.len, p, std::mem::take(&mut self.v_data));
        let seed = session_seed(self.spec.seed, session_epoch(self.len, self.spec.stride()));
        let inputs = AttnInputs::new(q, &k, &v).with_seed(seed);
        self.method.compute_into(&inputs, out, scratch);
        self.k_data = k.into_vec();
        self.v_data = v.into_vec();
    }
}

// ---------------------------------------------------------------------------
// Bounded (sliding-window) session
// ---------------------------------------------------------------------------

/// A sliding-window session: keeps only the last `window` appended
/// tokens in a ring, evicting oldest-first, and serves queries by
/// running the wrapped method over the current window — the bounded-state
/// decode loop for unbounded streams.
///
/// **Eviction is deterministic** (strictly oldest-first, a pure function
/// of the append sequence) and **epoch-correct**: the re-pilot epoch is
/// [`session_epoch`]`(appended_total, stride)` over the *total* appended
/// count, so sampling randomness keeps refreshing on the configured
/// stride after the window fills — a query is bitwise what a full
/// recompute over the window rows at [`session_seed`]`(spec.seed, epoch)`
/// produces.  Before the window fills, a `BoundedSession` is
/// byte-for-byte a [`RecomputeSession`].
///
/// ```
/// use skeinformer::attention::{self, AttentionSession, BoundedSession, SessionSpec};
/// use skeinformer::tensor::Matrix;
///
/// let method = attention::by_name("standard", 8).unwrap();
/// let mut s = BoundedSession::new(method, SessionSpec::new(2), 3);
/// for t in 0..5 {
///     s.append(&[t as f32, 0.0], &[t as f32, t as f32]);
/// }
/// assert_eq!(s.len(), 3); // tokens 0 and 1 evicted
/// assert_eq!(s.appended(), 5);
/// let out = s.query(&Matrix::zeros(1, 2)); // uniform scores: mean of V
/// assert!((out.get(0, 0) - 3.0).abs() < 1e-5); // mean of {2, 3, 4}
/// ```
pub struct BoundedSession {
    method: Box<dyn AttentionMethod>,
    spec: SessionSpec,
    window: usize,
    /// Ring storage (`window * head_dim` once full); slot `i` holds one
    /// token's row at `[i * head_dim ..][.. head_dim]`.
    k_ring: Vec<f32>,
    v_ring: Vec<f32>,
    /// Ring slot of the oldest retained token.
    start: usize,
    /// Tokens currently retained (`<= window`).
    filled: usize,
    /// Total tokens ever appended — the epoch basis.
    appended: usize,
}

impl BoundedSession {
    /// Wrap `method` with a sliding window of `window` tokens (clamped to
    /// ≥ 1).
    pub fn new(method: Box<dyn AttentionMethod>, spec: SessionSpec, window: usize) -> Self {
        let window = window.max(1);
        let reserve = window.min(spec.capacity_hint.max(1)) * spec.head_dim;
        Self {
            method,
            spec,
            window,
            k_ring: Vec::with_capacity(reserve),
            v_ring: Vec::with_capacity(reserve),
            start: 0,
            filled: 0,
            appended: 0,
        }
    }

    /// Total tokens ever appended (≥ [`len`](AttentionSession::len)).
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// The configured window length in tokens.
    pub fn window(&self) -> usize {
        self.window
    }
}

impl AttentionSession for BoundedSession {
    fn head_dim(&self) -> usize {
        self.spec.head_dim
    }

    /// Tokens currently retained — the length queries compute over.
    fn len(&self) -> usize {
        self.filled
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        let p = self.spec.head_dim;
        assert_eq!(k_row.len(), p, "k_row length != head_dim");
        assert_eq!(v_row.len(), p, "v_row length != head_dim");
        if self.filled < self.window {
            // ring still filling: slots are appended in order
            self.k_ring.extend_from_slice(k_row);
            self.v_ring.extend_from_slice(v_row);
            self.filled += 1;
        } else {
            // full: overwrite the oldest slot and advance the ring start
            let o = self.start * p;
            self.k_ring[o..o + p].copy_from_slice(k_row);
            self.v_ring[o..o + p].copy_from_slice(v_row);
            self.start = (self.start + 1) % self.window;
        }
        self.appended += 1;
    }

    fn query_into(&mut self, q: &Matrix, out: &mut Matrix, scratch: &mut AttnScratch) {
        assert!(self.filled > 0, "query on an empty session");
        assert_eq!(q.cols(), self.spec.head_dim, "query head_dim mismatch");
        let p = self.spec.head_dim;
        let n = self.filled;
        // materialise the window oldest-first — the exact row sequence an
        // unbounded session holding only these tokens would have
        let mut k = scratch.matrix(n, p);
        let mut v = scratch.matrix(n, p);
        for i in 0..n {
            let o = ((self.start + i) % self.window) * p;
            k.row_mut(i).copy_from_slice(&self.k_ring[o..o + p]);
            v.row_mut(i).copy_from_slice(&self.v_ring[o..o + p]);
        }
        let seed =
            session_seed(self.spec.seed, session_epoch(self.appended, self.spec.stride()));
        let inputs = AttnInputs::new(q, &k, &v).with_seed(seed);
        self.method.compute_into(&inputs, out, scratch);
        scratch.recycle(v);
        scratch.recycle(k);
    }
}

// ---------------------------------------------------------------------------
// VMean: exact O(p)-per-token incremental session
// ---------------------------------------------------------------------------

/// Streaming [`VMean`](super::VMean): maintains the running column sums of
/// V, so append is O(p) and a query fills rows with the current mean —
/// bitwise what a full recompute produces (same additions, same order).
pub struct VMeanSession {
    head_dim: usize,
    sums: Vec<f32>,
    len: usize,
}

impl VMeanSession {
    pub fn new(spec: SessionSpec) -> Self {
        Self { head_dim: spec.head_dim, sums: vec![0.0; spec.head_dim], len: 0 }
    }
}

impl AttentionSession for VMeanSession {
    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn len(&self) -> usize {
        self.len
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.head_dim, "k_row length != head_dim");
        assert_eq!(v_row.len(), self.head_dim, "v_row length != head_dim");
        // same accumulation masked_col_sums performs, one row at a time
        for (o, &x) in self.sums.iter_mut().zip(v_row) {
            *o += x;
        }
        self.len += 1;
    }

    fn query_into(&mut self, q: &Matrix, out: &mut Matrix, _scratch: &mut AttnScratch) {
        assert!(self.len > 0, "query on an empty session");
        assert_eq!(q.cols(), self.head_dim, "query head_dim mismatch");
        assert_eq!(out.shape(), (q.rows(), self.head_dim), "output shape mismatch");
        let m = self.len as f32;
        for i in 0..out.rows() {
            for (o, &s) in out.row_mut(i).iter_mut().zip(&self.sums) {
                *o = s / m;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Linformer: exact O(d·p)-per-token incremental session
// ---------------------------------------------------------------------------

/// Streaming [`Linformer`](super::Linformer): the sketch projections
/// `Kₚ = SᵀK` and `Vₚ = SᵀV` are maintained incrementally — appending
/// token `i` draws sketch row `S_(i)` from the session's RNG (the same
/// stream position the batch `gaussian_sketch` would use) and adds the
/// rank-1 updates `S_(i)ᵀ k_i` / `S_(i)ᵀ v_i`.  Queries then cost
/// O(m·d·p) regardless of context length, and the result is bitwise what
/// `Linformer::compute` at `Rng::new(spec.seed)` over the full K/V
/// produces: the per-accumulator addition order matches `matmul_tn`
/// exactly.
pub struct LinformerSession {
    head_dim: usize,
    d: usize,
    std: f32,
    rng: Rng,
    k_proj: Matrix,
    v_proj: Matrix,
    srow: Vec<f32>,
    len: usize,
}

impl LinformerSession {
    pub fn new(d: usize, spec: SessionSpec) -> Self {
        Self {
            head_dim: spec.head_dim,
            d,
            std: 1.0 / (d as f32).sqrt(),
            rng: Rng::new(spec.seed),
            k_proj: Matrix::zeros(d, spec.head_dim),
            v_proj: Matrix::zeros(d, spec.head_dim),
            srow: vec![0.0; d],
            len: 0,
        }
    }
}

impl AttentionSession for LinformerSession {
    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn len(&self) -> usize {
        self.len
    }

    fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.head_dim, "k_row length != head_dim");
        assert_eq!(v_row.len(), self.head_dim, "v_row length != head_dim");
        // sketch row i, drawn at the same stream position the batch
        // gaussian_sketch uses for row i
        for x in self.srow.iter_mut() {
            *x = self.rng.normal() * self.std;
        }
        // rank-1 updates in matmul_tn's accumulation order (including its
        // zero-coefficient skip), so the projections stay bitwise equal
        // to the batch path's — both sides route the row update through
        // the same dispatched saxpy kernel
        let kt = crate::tensor::kernels::active();
        for (c, &sc) in self.srow.iter().enumerate() {
            if sc == 0.0 {
                continue;
            }
            (kt.saxpy)(sc, k_row, self.k_proj.row_mut(c));
        }
        for (c, &sc) in self.srow.iter().enumerate() {
            if sc == 0.0 {
                continue;
            }
            (kt.saxpy)(sc, v_row, self.v_proj.row_mut(c));
        }
        self.len += 1;
    }

    fn query_into(&mut self, q: &Matrix, out: &mut Matrix, scratch: &mut AttnScratch) {
        assert!(self.len > 0, "query on an empty session");
        assert_eq!(q.cols(), self.head_dim, "query head_dim mismatch");
        assert_eq!(out.shape(), (q.rows(), self.head_dim), "output shape mismatch");
        let p = self.head_dim as f32;
        let mut scores = scratch.matrix(q.rows(), self.d);
        matmul_nt_into(q, &self.k_proj, &mut scores);
        scale_inplace(&mut scores, 1.0 / p.sqrt());
        softmax_rows(&mut scores);
        matmul_into(&scores, &self.v_proj, out);
        scratch.recycle(scores);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{Linformer, Standard, VMean};

    fn token_rows(n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = || {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            m
        };
        (mk(), mk(), mk())
    }

    #[test]
    fn standard_session_matches_exact_rows() {
        // decode shape: after appending i+1 tokens, querying with q row i
        // must reproduce row i of the square exact attention
        let (q, k, v) = token_rows(24, 8, 1);
        let exact = Standard::exact(&q, &k, &v, None);
        let mut session = Standard.begin_session(SessionSpec::new(8));
        let mut scratch = AttnScratch::new();
        for i in 0..24 {
            session.append(k.row(i), v.row(i));
            let qi = Matrix::from_vec(1, 8, q.row(i).to_vec());
            let mut out = Matrix::zeros(1, 8);
            session.query_into(&qi, &mut out, &mut scratch);
            for j in 0..8 {
                assert!(
                    (out.get(0, j) - exact.get(i, j)).abs() < 1e-5,
                    "token {i} col {j}"
                );
            }
        }
    }

    #[test]
    fn vmean_session_is_bitwise_running_mean() {
        let (q, k, v) = token_rows(16, 4, 2);
        let mut session = VMean.begin_session(SessionSpec::new(4));
        for i in 0..16 {
            session.append(k.row(i), v.row(i));
        }
        let got = session.query(&q);
        let want = VMean.compute(&q, &k, &v, None, &mut Rng::new(0));
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn linformer_session_matches_batch_sketch_bitwise() {
        let (q, k, v) = token_rows(32, 8, 3);
        let seed = 11u64;
        let lin = Linformer::new(6);
        let mut session = lin.begin_session(SessionSpec::new(8).with_seed(seed));
        for i in 0..32 {
            session.append(k.row(i), v.row(i));
        }
        let got = session.query(&q);
        let want = lin.compute(&q, &k, &v, None, &mut Rng::new(seed));
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn recompute_session_uses_epoch_seed() {
        use crate::attention::Skeinformer;
        let (q, k, v) = token_rows(20, 8, 4);
        let skein = Skeinformer::new(8);
        let spec = SessionSpec::new(8).with_seed(5).with_repilot_stride(4);
        let mut session = skein.begin_session(spec);
        for i in 0..20 {
            session.append(k.row(i), v.row(i));
        }
        let got = session.query(&q);
        let seed = session_seed(5, session_epoch(20, 4));
        let want = skein.compute(&q, &k, &v, None, &mut Rng::new(seed));
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn epoch_advances_on_stride() {
        assert_eq!(session_epoch(0, 4), 0);
        assert_eq!(session_epoch(3, 4), 0);
        assert_eq!(session_epoch(4, 4), 1);
        assert_eq!(session_epoch(8, 1), 8);
        // stride 0 clamps to 1 instead of dividing by zero
        assert_eq!(session_epoch(8, 0), 8);
        assert_ne!(session_seed(5, 0), session_seed(5, 1));
    }

    #[test]
    #[should_panic]
    fn empty_session_query_panics() {
        let mut s = Standard.begin_session(SessionSpec::new(4));
        let q = Matrix::zeros(1, 4);
        let _ = s.query(&q);
    }

    #[test]
    fn bounded_session_matches_window_recompute_at_epoch_seed() {
        use crate::attention::Skeinformer;
        let (q, k, v) = token_rows(20, 8, 6);
        let window = 8;
        let spec = SessionSpec::new(8).with_seed(9).with_repilot_stride(4);
        let mut session = BoundedSession::new(Box::new(Skeinformer::new(4)), spec, window);
        for i in 0..20 {
            session.append(k.row(i), v.row(i));
        }
        assert_eq!(session.len(), window);
        assert_eq!(session.appended(), 20);
        let q1 = Matrix::from_vec(1, 8, q.row(0).to_vec());
        let got = session.query(&q1);
        // expected: the wrapped method over the last `window` rows at the
        // epoch seed derived from the TOTAL appended count
        let idx: Vec<usize> = (12..20).collect();
        let kw = k.gather_rows(&idx);
        let vw = v.gather_rows(&idx);
        let seed = session_seed(9, session_epoch(20, 4));
        let want = Skeinformer::new(4).compute(&q1, &kw, &vw, None, &mut Rng::new(seed));
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn bounded_session_is_recompute_before_window_fills() {
        use crate::attention::Skeinformer;
        let (q, k, v) = token_rows(6, 8, 7);
        let spec = SessionSpec::new(8).with_seed(3);
        let mut bounded = BoundedSession::new(Box::new(Skeinformer::new(4)), spec, 16);
        let mut plain = RecomputeSession::new(Skeinformer::new(4), spec);
        for i in 0..6 {
            bounded.append(k.row(i), v.row(i));
            plain.append(k.row(i), v.row(i));
        }
        let got = bounded.query(&q);
        let want = plain.query(&q);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn bounded_eviction_is_strictly_oldest_first() {
        // exact check via Standard: after wrapping several times, a query
        // must see exactly the last `window` tokens in order
        let window = 4;
        let spec = SessionSpec::new(4).with_seed(0);
        let mut session = BoundedSession::new(Box::new(Standard), spec, window);
        let (q, k, v) = token_rows(11, 4, 8);
        for i in 0..11 {
            session.append(k.row(i), v.row(i));
        }
        let idx: Vec<usize> = (7..11).collect();
        let kw = k.gather_rows(&idx);
        let vw = v.gather_rows(&idx);
        let q1 = Matrix::from_vec(1, 4, q.row(0).to_vec());
        let want = Standard::exact(&q1, &kw, &vw, None);
        let got = session.query(&q1);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }
}
