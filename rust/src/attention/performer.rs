//! Performer (Choromanski et al. 2020) — FAVOR+ linear attention with
//! positive softmax random features:
//!
//! `phi(x) = exp(ω·x − ‖x‖²/2) / √m`,  Attention ≈ φ(Q)(φ(K)ᵀV) / φ(Q)(φ(K)ᵀ1)
//!
//! One of the Table-1/2 baselines; the paper groups it with methods that
//! decompose the score matrix without strictly approximating the original
//! attention (§2), and its LRA behaviour (strong on Text, weak on
//! Pathfinder) is part of the reproduced shape.

use super::{check_inputs, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_nt, matmul_tn, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct Performer {
    /// Number of random features m.
    pub m: usize,
}

impl Performer {
    pub fn new(m: usize) -> Self {
        Self { m }
    }

    /// Positive random-feature map with a shared max-subtraction for
    /// numerical stability (standard FAVOR+ stabilisation).
    fn features(x: &Matrix, w: &Matrix) -> Matrix {
        let m = w.rows();
        let mut proj = matmul_nt(x, w); // (n, m): rows ω·x
        // subtract ‖x‖²/2 per row, then global max
        let mut gmax = f32::NEG_INFINITY;
        for i in 0..x.rows() {
            let sq: f32 = x.row(i).iter().map(|a| a * a).sum::<f32>() * 0.5;
            for z in proj.row_mut(i) {
                *z -= sq;
                gmax = gmax.max(*z);
            }
        }
        let inv_sqrt_m = 1.0 / (m as f32).sqrt();
        for z in proj.data_mut() {
            *z = (*z - gmax).exp() * inv_sqrt_m;
        }
        proj
    }
}

impl AttentionMethod for Performer {
    fn name(&self) -> &'static str {
        "performer"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let n = q.rows();
        let p = q.cols();
        // 1/√√p scaling splits the softmax temperature between Q and K.
        let scale = 1.0 / (p as f32).sqrt().sqrt();
        let qs = Matrix::from_fn(n, p, |i, j| q.get(i, j) * scale);
        let ks = Matrix::from_fn(n, p, |i, j| k.get(i, j) * scale);
        let mut w = Matrix::zeros(self.m, p);
        rng.fill_normal(w.data_mut());

        let qp = Self::features(&qs, &w); // (n, m)
        let mut kp = Self::features(&ks, &w); // (n, m)
        if let Some(m) = mask {
            for i in 0..n {
                if m[i] <= 0.0 {
                    kp.row_mut(i).iter_mut().for_each(|x| *x = 0.0);
                }
            }
        }
        let kv = matmul_tn(&kp, v); // (m, p)
        let norm = crate::tensor::col_sums(&kp); // φ(K)ᵀ1 : (m,)
        let out = matmul(&qp, &kv); // (n, p)
        let denom: Vec<f32> = (0..n)
            .map(|i| {
                crate::tensor::dot(qp.row(i), &norm).max(1e-30)
            })
            .collect();
        Matrix::from_fn(n, v.cols(), |i, j| out.get(i, j) / denom[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::{scale_inplace, spectral_norm_diff};

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn rows_are_convex_combinations_of_v() {
        let (q, k, v) = qkv(64, 8, 1, 0.7);
        let out = Performer::new(64).compute(&q, &k, &v, None, &mut Rng::new(2));
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            assert!(x <= vmax + 1e-3 && x >= vmin - 1e-3);
        }
    }

    #[test]
    fn approximates_softmax_on_mild_inputs() {
        // FAVOR+ is unbiased for the softmax kernel; with many features and
        // small logits the relative error should be modest.
        let (q, k, v) = qkv(64, 8, 3, 0.5);
        let exact = Standard::exact(&q, &k, &v, None);
        let mut err = 0.0;
        let trials = 6;
        for s in 0..trials {
            let out = Performer::new(256).compute(&q, &k, &v, None, &mut Rng::new(10 + s));
            err += spectral_norm_diff(&out, &exact);
        }
        err /= trials as f32;
        let base = crate::tensor::spectral_norm(&exact);
        assert!(err / base < 0.5, "relative err {}", err / base);
    }

    #[test]
    fn more_features_reduce_error() {
        let (q, k, v) = qkv(64, 8, 5, 0.8);
        let exact = Standard::exact(&q, &k, &v, None);
        let mean_err = |m: usize| {
            (0..8)
                .map(|s| {
                    spectral_norm_diff(
                        &Performer::new(m).compute(&q, &k, &v, None, &mut Rng::new(30 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 8.0
        };
        assert!(mean_err(256) < mean_err(16));
    }

    #[test]
    fn masked_keys_contribute_nothing() {
        let (q, k, v) = qkv(32, 8, 7, 0.6);
        let mut mask = vec![1.0f32; 32];
        for m in mask.iter_mut().skip(24) {
            *m = 0.0;
        }
        let perf = Performer::new(64);
        let a = perf.compute(&q, &k, &v, Some(&mask), &mut Rng::new(4));
        let mut v2 = v.clone();
        for i in 24..32 {
            for j in 0..8 {
                v2.set(i, j, 1e5);
            }
        }
        let b = perf.compute(&q, &k, &v2, Some(&mask), &mut Rng::new(4));
        assert!(a.max_abs_diff(&b) < 1e-3);
    }
}
