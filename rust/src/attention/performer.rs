//! Performer (Choromanski et al. 2020) — FAVOR+ linear attention with
//! positive softmax random features:
//!
//! `phi(x) = exp(ω·x − ‖x‖²/2) / √m`,  Attention ≈ φ(Q)(φ(K)ᵀV) / φ(Q)(φ(K)ᵀ1)
//!
//! One of the Table-1/2 baselines; the paper groups it with methods that
//! decompose the score matrix without strictly approximating the original
//! attention (§2), and its LRA behaviour (strong on Text, weak on
//! Pathfinder) is part of the reproduced shape.

use super::{
    check_inputs, AttentionMethod, AttentionSession, AttnInputs, AttnScratch, RecomputeSession,
    SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct Performer {
    /// Number of random features m.
    pub m: usize,
}

impl Performer {
    pub fn new(m: usize) -> Self {
        Self { m }
    }

    /// Positive random-feature map with a shared max-subtraction for
    /// numerical stability (standard FAVOR+ stabilisation), into `proj`
    /// (shape `(x.rows(), w.rows())`, fully overwritten).
    fn features_into(x: &Matrix, w: &Matrix, proj: &mut Matrix) {
        let m = w.rows();
        matmul_nt_into(x, w, proj); // (n, m): rows ω·x
        // subtract ‖x‖²/2 per row, then global max
        let mut gmax = f32::NEG_INFINITY;
        for i in 0..x.rows() {
            let sq: f32 = x.row(i).iter().map(|a| a * a).sum::<f32>() * 0.5;
            for z in proj.row_mut(i) {
                *z -= sq;
                gmax = gmax.max(*z);
            }
        }
        let inv_sqrt_m = 1.0 / (m as f32).sqrt();
        for z in proj.data_mut() {
            *z = (*z - gmax).exp() * inv_sqrt_m;
        }
    }
}

impl AttentionMethod for Performer {
    fn name(&self) -> &'static str {
        "performer"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, inputs.mask);
        let m_rows = q.rows();
        let n = k.rows();
        let p = q.cols();
        // 1/√√p scaling splits the softmax temperature between Q and K.
        let scale = 1.0 / (p as f32).sqrt().sqrt();
        let mut qs = scratch.matrix(m_rows, p);
        for i in 0..m_rows {
            for (o, &x) in qs.row_mut(i).iter_mut().zip(q.row(i)) {
                *o = x * scale;
            }
        }
        let mut ks = scratch.matrix(n, p);
        for i in 0..n {
            for (o, &x) in ks.row_mut(i).iter_mut().zip(k.row(i)) {
                *o = x * scale;
            }
        }
        let mut w = scratch.matrix(self.m, p);
        rng.fill_normal(w.data_mut());

        let mut qp = scratch.matrix(m_rows, self.m); // (m_rows, m)
        Self::features_into(&qs, &w, &mut qp);
        scratch.recycle(qs);
        let mut kp = scratch.matrix(n, self.m); // (n, m)
        Self::features_into(&ks, &w, &mut kp);
        scratch.recycle(ks);
        scratch.recycle(w);
        if let Some(m) = inputs.mask {
            for i in 0..n {
                if m[i] <= 0.0 {
                    kp.row_mut(i).iter_mut().for_each(|x| *x = 0.0);
                }
            }
        }
        let mut kv = scratch.matrix(self.m, v.cols()); // (m, p)
        matmul_tn_into(&kp, v, &mut kv);
        let mut norm = scratch.buf(self.m); // φ(K)ᵀ1 : (m,)
        crate::tensor::col_sums_into(&kp, &mut norm);
        scratch.recycle(kp);
        matmul_into(&qp, &kv, out); // (m_rows, p)
        scratch.recycle(kv);
        for i in 0..m_rows {
            let denom = crate::tensor::dot(qp.row(i), &norm).max(1e-30);
            out.row_mut(i).iter_mut().for_each(|x| *x /= denom);
        }
        scratch.recycle_buf(norm);
        scratch.recycle(qp);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // FAVOR+ features are drawn per call; the session recomputes with
        // the epoch seed so features refresh on the re-pilot stride
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::{scale_inplace, spectral_norm_diff};

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn rows_are_convex_combinations_of_v() {
        let (q, k, v) = qkv(64, 8, 1, 0.7);
        let out = Performer::new(64).compute(&q, &k, &v, None, &mut Rng::new(2));
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            assert!(x <= vmax + 1e-3 && x >= vmin - 1e-3);
        }
    }

    #[test]
    fn approximates_softmax_on_mild_inputs() {
        // FAVOR+ is unbiased for the softmax kernel; with many features and
        // small logits the relative error should be modest.
        let (q, k, v) = qkv(64, 8, 3, 0.5);
        let exact = Standard::exact(&q, &k, &v, None);
        let mut err = 0.0;
        let trials = 6;
        for s in 0..trials {
            let out = Performer::new(256).compute(&q, &k, &v, None, &mut Rng::new(10 + s));
            err += spectral_norm_diff(&out, &exact);
        }
        err /= trials as f32;
        let base = crate::tensor::spectral_norm(&exact);
        assert!(err / base < 0.5, "relative err {}", err / base);
    }

    #[test]
    fn more_features_reduce_error() {
        let (q, k, v) = qkv(64, 8, 5, 0.8);
        let exact = Standard::exact(&q, &k, &v, None);
        let mean_err = |m: usize| {
            (0..8)
                .map(|s| {
                    spectral_norm_diff(
                        &Performer::new(m).compute(&q, &k, &v, None, &mut Rng::new(30 + s)),
                        &exact,
                    )
                })
                .sum::<f32>()
                / 8.0
        };
        assert!(mean_err(256) < mean_err(16));
    }

    #[test]
    fn masked_keys_contribute_nothing() {
        let (q, k, v) = qkv(32, 8, 7, 0.6);
        let mut mask = vec![1.0f32; 32];
        for m in mask.iter_mut().skip(24) {
            *m = 0.0;
        }
        let perf = Performer::new(64);
        let a = perf.compute(&q, &k, &v, Some(&mask), &mut Rng::new(4));
        let mut v2 = v.clone();
        for i in 24..32 {
            for j in 0..8 {
                v2.set(i, j, 1e5);
            }
        }
        let b = perf.compute(&q, &k, &v2, Some(&mask), &mut Rng::new(4));
        assert!(a.max_abs_diff(&b) < 1e-3);
    }
}
