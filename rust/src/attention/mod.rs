//! Pure-rust implementations of every attention method in the paper.
//!
//! These power the Figure-1 approximation study, the scaling benches
//! (E8), the property suites, and the serving example's CPU fallback.
//! Each file implements one method; all share the [`AttentionMethod`]
//! interface:
//!
//! ```
//! use skeinformer::attention::{AttentionMethod, Standard};
//! use skeinformer::tensor::Matrix;
//! use skeinformer::rng::Rng;
//!
//! let n = 64;
//! let q = Matrix::from_fn(n, 16, |i, j| ((i + j) as f32 * 0.1).sin());
//! let out = Standard.compute(&q, &q, &q, None, &mut Rng::new(0));
//! assert_eq!(out.shape(), (n, 16));
//! ```
//!
//! Methods are registered by the same names the python layer uses
//! (`attention.METHODS`), so experiment configs work across layers.
//!
//! The single-matrix call above is the unit of work; realistic workloads
//! (many sequences × many heads) go through [`BatchedAttention`], which
//! dispatches every method over a `B × H` grid of head slices with
//! deterministic per-head RNG streams.

mod batch;
mod bigbird;
mod informer;
mod linformer;
pub mod masking;
mod nystromformer;
mod performer;
mod reformer;
mod skeinformer;
mod standard;
mod vmean;

pub use batch::{BatchedAttention, HeadSpec};
pub use bigbird::BigBird;
pub use informer::Informer;
pub use linformer::{Linformer, LinformerUnreducedJlt};
pub use nystromformer::Nystromformer;
pub use performer::Performer;
pub use reformer::Reformer;
pub use skeinformer::{RowNorm, Skeinformer};
pub use standard::Standard;
pub use vmean::VMean;

use crate::rng::Rng;
use crate::tensor::Matrix;

/// A drop-in self-attention approximation: given Q, K, V (all `n×p`) and an
/// optional padding mask (length-n 0/1 weights), produce the `n×p` output.
///
/// Implementations draw any sampling randomness from the supplied [`Rng`],
/// so a fixed seed reproduces a run exactly (the discipline the AOT
/// artifacts follow with their `seed` input).
pub trait AttentionMethod: Sync {
    /// Registry name (matches `python/compile/attention.py`).
    fn name(&self) -> &'static str;

    /// Compute the (approximate) attention output.
    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix;

    /// Whether the method is exact (no approximation error).
    fn is_exact(&self) -> bool {
        false
    }
}

/// Validate the shared preconditions; every implementation calls this.
pub(crate) fn check_inputs(q: &Matrix, k: &Matrix, v: &Matrix, mask: Option<&[f32]>) {
    assert_eq!(q.cols(), k.cols(), "Q/K head dims differ");
    assert_eq!(k.rows(), v.rows(), "K/V lengths differ");
    assert_eq!(q.rows(), k.rows(), "self-attention requires square n");
    if let Some(m) = mask {
        assert_eq!(m.len(), k.rows(), "mask length mismatch");
    }
}

/// Build every method at a given feature budget `d` — the registry used by
/// the Figure-1 bench and the CLI. Order matches the paper's Table 1 rows.
pub fn registry(d: usize) -> Vec<Box<dyn AttentionMethod>> {
    vec![
        Box::new(Standard),
        Box::new(VMean),
        Box::new(Skeinformer::new(d)),
        Box::new(Skeinformer::new(d).uniform_sampling()),
        Box::new(Skeinformer::new(d).row_norm(RowNorm::None)),
        Box::new(Skeinformer::new(d).row_norm(RowNorm::Simple)),
        Box::new(Skeinformer::new(d).without_psr()),
        Box::new(Informer::new(d)),
        Box::new(Informer::new(d).with_padding_mask()),
        Box::new(Linformer::new(d)),
        Box::new(LinformerUnreducedJlt::new(d)),
        Box::new(Performer::new(d)),
        Box::new(Nystromformer::new(d)),
        Box::new(BigBird::default()),
        Box::new(Reformer::default()),
    ]
}

/// Look a method up by registry name.
pub fn by_name(name: &str, d: usize) -> Option<Box<dyn AttentionMethod>> {
    registry(d).into_iter().find(|m| m.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Matrix, Matrix, Matrix) {
        let n = 64;
        let p = 16;
        let q = Matrix::from_fn(n, p, |i, j| ((i * 3 + j) as f32 * 0.13).sin());
        let k = Matrix::from_fn(n, p, |i, j| ((i + j * 5) as f32 * 0.07).cos());
        let v = Matrix::from_fn(n, p, |i, j| ((i * j) as f32 * 0.01).tanh());
        (q, k, v)
    }

    #[test]
    fn registry_names_are_unique_and_complete() {
        let reg = registry(16);
        let names: std::collections::HashSet<_> = reg.iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), reg.len(), "duplicate names");
        for expect in [
            "standard",
            "vmean",
            "skeinformer",
            "skein_uniform",
            "skein_no_norm",
            "skein_simple_norm",
            "skein_no_psr",
            "informer",
            "informer_mask",
            "linformer",
            "linformer_jlt",
            "performer",
            "nystromformer",
            "bigbird",
            "reformer",
        ] {
            assert!(names.contains(expect), "missing {expect}");
        }
    }

    #[test]
    fn every_method_produces_finite_output_of_right_shape() {
        let (q, k, v) = toy();
        for m in registry(16) {
            let mut rng = Rng::new(1);
            let out = m.compute(&q, &k, &v, None, &mut rng);
            assert_eq!(out.shape(), v.shape(), "{}", m.name());
            assert!(out.all_finite(), "{} produced non-finite values", m.name());
        }
    }

    #[test]
    fn every_method_is_deterministic_given_seed() {
        let (q, k, v) = toy();
        for m in registry(16) {
            let a = m.compute(&q, &k, &v, None, &mut Rng::new(33));
            let b = m.compute(&q, &k, &v, None, &mut Rng::new(33));
            assert_eq!(a.max_abs_diff(&b), 0.0, "{} not deterministic", m.name());
        }
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("skeinformer", 8).is_some());
        assert!(by_name("nope", 8).is_none());
    }
}
