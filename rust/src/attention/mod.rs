//! Pure-rust implementations of every attention method in the paper.
//!
//! These power the Figure-1 approximation study, the scaling benches
//! (E8), the property suites, and the serving stack's CPU engine.  Each
//! file implements one method; all share the [`AttentionMethod`]
//! interface, which has two entry points:
//!
//! * [`compute_into`](AttentionMethod::compute_into) — the v2
//!   zero-allocation path: borrowed inputs ([`AttnInputs`]), a
//!   caller-provided output, and recycled temporaries ([`AttnScratch`]).
//! * [`compute`](AttentionMethod::compute) — the legacy allocating call,
//!   kept as a thin wrapper so existing callers migrate incrementally.
//!
//! ```
//! use skeinformer::attention::{AttentionMethod, AttnInputs, AttnScratch, Standard};
//! use skeinformer::tensor::Matrix;
//! use skeinformer::rng::Rng;
//!
//! let n = 64;
//! let q = Matrix::from_fn(n, 16, |i, j| ((i + j) as f32 * 0.1).sin());
//!
//! // legacy allocating call
//! let out = Standard.compute(&q, &q, &q, None, &mut Rng::new(0));
//! assert_eq!(out.shape(), (n, 16));
//!
//! // v2: same bytes, no allocation — output and temporaries are reused
//! let mut out2 = Matrix::zeros(n, 16);
//! let mut scratch = AttnScratch::new();
//! Standard.compute_into(&AttnInputs::new(&q, &q, &q), &mut out2, &mut scratch);
//! assert_eq!(out.max_abs_diff(&out2), 0.0);
//! ```
//!
//! Methods are registered by the same names the python layer uses
//! (`attention.METHODS`), so experiment configs work across layers.
//!
//! The single-matrix call above is the unit of work; realistic workloads
//! (many sequences × many heads) go through [`BatchedAttention`], which
//! dispatches every method over a `B × H` grid of head slices with
//! deterministic per-head RNG streams, and autoregressive decode goes
//! through [`AttentionSession`]s
//! ([`begin_session`](AttentionMethod::begin_session)).

mod batch;
mod bigbird;
mod informer;
mod linformer;
pub mod masking;
mod nystromformer;
mod performer;
mod reformer;
mod scratch;
mod session;
mod skeinformer;
mod standard;
mod vmean;

pub use batch::{BatchedAttention, HeadSpec};
pub use bigbird::BigBird;
pub use informer::Informer;
pub use linformer::{Linformer, LinformerUnreducedJlt};
pub use nystromformer::Nystromformer;
pub use performer::Performer;
pub use reformer::Reformer;
pub use scratch::AttnScratch;
pub use session::{
    session_epoch, session_seed, AttentionSession, BoundedSession, LinformerSession,
    RecomputeSession, SessionSpec, VMeanSession,
};
pub use skeinformer::{RowNorm, Skeinformer};
pub use standard::Standard;
pub use vmean::VMean;

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Borrowed inputs for one attention computation: `m×p` queries against
/// `n×p` keys/values, an optional length-`n` 0/1 padding mask over key
/// positions, and the seed any sampling randomness derives from.
///
/// This is a plain view struct — it borrows, never owns, so building one
/// costs nothing and the borrows pin the caller's buffers for exactly the
/// duration of the call.  `m == n` (self-attention) is the classic shape;
/// `m != n` (cross-shape, e.g. a one-row decode query against a long key
/// cache) is accepted by methods whose
/// [`supports_cross_shape`](AttentionMethod::supports_cross_shape) is true.
///
/// ```
/// use skeinformer::attention::AttnInputs;
/// use skeinformer::tensor::Matrix;
///
/// let q = Matrix::zeros(2, 8); // m = 2 decode queries
/// let k = Matrix::zeros(64, 8); // n = 64 cached keys
/// let v = Matrix::zeros(64, 8);
/// let inputs = AttnInputs::new(&q, &k, &v).with_seed(7);
/// assert_eq!(inputs.out_shape(), (2, 8));
/// ```
#[derive(Clone, Copy, Debug)]
pub struct AttnInputs<'a> {
    /// Queries, `m × p`.
    pub q: &'a Matrix,
    /// Keys, `n × p`.
    pub k: &'a Matrix,
    /// Values, `n × p`.
    pub v: &'a Matrix,
    /// Optional length-`n` 0/1 weights over key positions.
    pub mask: Option<&'a [f32]>,
    /// Seed for sampling randomness ([`AttentionMethod::compute_into`]
    /// draws from `Rng::new(seed)`).
    pub seed: u64,
}

impl<'a> AttnInputs<'a> {
    /// Unmasked inputs with seed 0.
    pub fn new(q: &'a Matrix, k: &'a Matrix, v: &'a Matrix) -> Self {
        Self { q, k, v, mask: None, seed: 0 }
    }

    /// Attach a padding mask (length `k.rows()`).
    pub fn with_mask(mut self, mask: Option<&'a [f32]>) -> Self {
        self.mask = mask;
        self
    }

    /// Set the sampling seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The output shape: `(q.rows(), v.cols())`.
    pub fn out_shape(&self) -> (usize, usize) {
        (self.q.rows(), self.v.cols())
    }

    /// True when queries and keys have the same row count (classic
    /// self-attention shape).
    pub fn is_square(&self) -> bool {
        self.q.rows() == self.k.rows()
    }
}

/// A drop-in self-attention approximation: given Q (`m×p`), K, V (both
/// `n×p`) and an optional padding mask (length-n 0/1 weights over keys),
/// produce the `m×p` output.
///
/// Implementations draw any sampling randomness from the supplied seed /
/// [`Rng`], so a fixed seed reproduces a run exactly (the discipline the
/// AOT artifacts follow with their `seed` input).
///
/// Implementors provide [`compute_rng_into`](Self::compute_rng_into) (and
/// [`begin_session`](Self::begin_session)); the allocating
/// [`compute`](Self::compute) and the seeded
/// [`compute_into`](Self::compute_into) are derived wrappers, guaranteed
/// bitwise-consistent with each other: `compute` with `Rng::new(s)`
/// produces exactly the bytes `compute_into` produces with `seed = s`.
///
/// `Send + Sync` are supertraits so boxed methods can move into session
/// wrappers ([`BoundedSession`]) and be shared across the worker pool —
/// every registry method is plain configuration data.
pub trait AttentionMethod: Send + Sync {
    /// Registry name (matches `python/compile/attention.py`).
    fn name(&self) -> &'static str;

    /// Core computation: write the attention output for `inputs` into
    /// `out` (shape [`AttnInputs::out_shape`]), drawing temporaries from
    /// `scratch` and randomness from `rng`.  `out` is fully overwritten —
    /// callers may pass a dirty reused buffer.
    ///
    /// This is the one method implementations define; prefer calling
    /// [`compute_into`](Self::compute_into) (seeded) or
    /// [`compute`](Self::compute) (allocating) instead.
    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    );

    /// v2 entry point: compute into a caller-provided output with
    /// recycled temporaries, seeding randomness from `inputs.seed`.
    ///
    /// Bitwise identical to [`compute`](Self::compute) called with
    /// `Rng::new(inputs.seed)`.
    ///
    /// ```
    /// use skeinformer::attention::{AttentionMethod, AttnInputs, AttnScratch, Skeinformer};
    /// use skeinformer::rng::Rng;
    /// use skeinformer::tensor::Matrix;
    ///
    /// let q = Matrix::from_fn(32, 8, |i, j| ((i * 3 + j) as f32 * 0.1).sin());
    /// let method = Skeinformer::new(8);
    /// let mut out = Matrix::zeros(32, 8);
    /// let mut scratch = AttnScratch::new();
    /// method.compute_into(&AttnInputs::new(&q, &q, &q).with_seed(5), &mut out, &mut scratch);
    /// let legacy = method.compute(&q, &q, &q, None, &mut Rng::new(5));
    /// assert_eq!(out.max_abs_diff(&legacy), 0.0);
    /// ```
    fn compute_into(&self, inputs: &AttnInputs<'_>, out: &mut Matrix, scratch: &mut AttnScratch) {
        // validated here once, so every method's write loops (including
        // the zip-based ones that would silently truncate) are safe
        assert_eq!(
            out.shape(),
            inputs.out_shape(),
            "{}: output shape mismatch (expected {:?})",
            self.name(),
            inputs.out_shape()
        );
        let mut rng = Rng::new(inputs.seed);
        self.compute_rng_into(inputs, &mut rng, out, scratch);
    }

    /// Legacy v1 entry point: allocate and return the output.  A thin
    /// wrapper over [`compute_rng_into`](Self::compute_rng_into), kept so
    /// existing callers migrate incrementally.
    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        let inputs = AttnInputs::new(q, k, v).with_mask(mask);
        let mut out = Matrix::zeros(q.rows(), v.cols());
        let mut scratch = AttnScratch::new();
        self.compute_rng_into(&inputs, rng, &mut out, &mut scratch);
        out
    }

    /// Whether the method is exact (no approximation error).
    fn is_exact(&self) -> bool {
        false
    }

    /// Whether `m×p` queries against `n×p` keys (`m != n`) are supported.
    /// Methods whose structure ties query position `i` to key position
    /// `i` (Reformer's shared QK projection, BigBird's window pattern)
    /// return false and panic with a clear message on cross-shape inputs.
    fn supports_cross_shape(&self) -> bool {
        false
    }

    /// Whether [`begin_session`](Self::begin_session) returns an *exact
    /// incremental* session (O(1)-per-token state, no stored K/V, queries
    /// independent of the re-pilot stride) — true for `vmean` and
    /// `linformer` only.  The serving layer uses this to decide whether a
    /// cache-backed stream still benefits from a live session: recompute
    /// sessions duplicate the KV cache's storage and are replaced by
    /// cache reads, while exact-incremental sessions keep their O(p) /
    /// O(d·p) state alongside the cache.
    fn session_is_exact_incremental(&self) -> bool {
        false
    }

    /// Open a stateful streaming session for autoregressive decode:
    /// append `(k_row, v_row)` tokens one at a time, query any number of
    /// `m×p` query rows against everything appended so far.  See
    /// [`AttentionSession`] for the exactness/re-pilot contract.
    ///
    /// ```
    /// use skeinformer::attention::{AttentionMethod, SessionSpec, Standard};
    /// use skeinformer::tensor::Matrix;
    ///
    /// let mut session = Standard.begin_session(SessionSpec::new(4).with_seed(1));
    /// session.append(&[1.0, 0.0, 0.0, 0.0], &[2.0, 2.0, 2.0, 2.0]);
    /// session.append(&[0.0, 1.0, 0.0, 0.0], &[4.0, 4.0, 4.0, 4.0]);
    /// let q = Matrix::zeros(1, 4); // uniform scores -> mean of V
    /// let out = session.query(&q);
    /// assert!((out.get(0, 0) - 3.0).abs() < 1e-5);
    /// ```
    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession>;
}

/// Validate the shared preconditions; every implementation calls this.
/// `cross_ok` is the method's `supports_cross_shape()` capability: when
/// false, non-square inputs panic with a message naming the method.
pub(crate) fn check_inputs(
    name: &str,
    cross_ok: bool,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: Option<&[f32]>,
) {
    assert_eq!(q.cols(), k.cols(), "{name}: Q/K head dims differ");
    assert_eq!(k.rows(), v.rows(), "{name}: K/V lengths differ");
    if !cross_ok {
        assert_eq!(
            q.rows(),
            k.rows(),
            "{name} ties query position i to key position i and requires square n×p inputs \
             (got {}×{} queries vs {}×{} keys); use a method with supports_cross_shape() for \
             m×p decode queries",
            q.rows(),
            q.cols(),
            k.rows(),
            k.cols()
        );
    }
    if let Some(m) = mask {
        assert_eq!(m.len(), k.rows(), "{name}: mask length mismatch");
    }
}

/// Build every method at a given feature budget `d` — the registry used by
/// the Figure-1 bench and the CLI. Order matches the paper's Table 1 rows.
pub fn registry(d: usize) -> Vec<Box<dyn AttentionMethod>> {
    vec![
        Box::new(Standard),
        Box::new(VMean),
        Box::new(Skeinformer::new(d)),
        Box::new(Skeinformer::new(d).uniform_sampling()),
        Box::new(Skeinformer::new(d).row_norm(RowNorm::None)),
        Box::new(Skeinformer::new(d).row_norm(RowNorm::Simple)),
        Box::new(Skeinformer::new(d).without_psr()),
        Box::new(Informer::new(d)),
        Box::new(Informer::new(d).with_padding_mask()),
        Box::new(Linformer::new(d)),
        Box::new(LinformerUnreducedJlt::new(d)),
        Box::new(Performer::new(d)),
        Box::new(Nystromformer::new(d)),
        Box::new(BigBird::default()),
        Box::new(Reformer::default()),
    ]
}

/// Look a method up by registry name.
pub fn by_name(name: &str, d: usize) -> Option<Box<dyn AttentionMethod>> {
    registry(d).into_iter().find(|m| m.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Matrix, Matrix, Matrix) {
        let n = 64;
        let p = 16;
        let q = Matrix::from_fn(n, p, |i, j| ((i * 3 + j) as f32 * 0.13).sin());
        let k = Matrix::from_fn(n, p, |i, j| ((i + j * 5) as f32 * 0.07).cos());
        let v = Matrix::from_fn(n, p, |i, j| ((i * j) as f32 * 0.01).tanh());
        (q, k, v)
    }

    #[test]
    fn registry_names_are_unique_and_complete() {
        let reg = registry(16);
        let names: std::collections::HashSet<_> = reg.iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), reg.len(), "duplicate names");
        for expect in [
            "standard",
            "vmean",
            "skeinformer",
            "skein_uniform",
            "skein_no_norm",
            "skein_simple_norm",
            "skein_no_psr",
            "informer",
            "informer_mask",
            "linformer",
            "linformer_jlt",
            "performer",
            "nystromformer",
            "bigbird",
            "reformer",
        ] {
            assert!(names.contains(expect), "missing {expect}");
        }
    }

    #[test]
    fn every_method_produces_finite_output_of_right_shape() {
        let (q, k, v) = toy();
        for m in registry(16) {
            let mut rng = Rng::new(1);
            let out = m.compute(&q, &k, &v, None, &mut rng);
            assert_eq!(out.shape(), v.shape(), "{}", m.name());
            assert!(out.all_finite(), "{} produced non-finite values", m.name());
        }
    }

    #[test]
    fn every_method_is_deterministic_given_seed() {
        let (q, k, v) = toy();
        for m in registry(16) {
            let a = m.compute(&q, &k, &v, None, &mut Rng::new(33));
            let b = m.compute(&q, &k, &v, None, &mut Rng::new(33));
            assert_eq!(a.max_abs_diff(&b), 0.0, "{} not deterministic", m.name());
        }
    }

    #[test]
    fn compute_into_matches_legacy_compute_bitwise() {
        let (q, k, v) = toy();
        let mut scratch = AttnScratch::new();
        for m in registry(16) {
            let legacy = m.compute(&q, &k, &v, None, &mut Rng::new(9));
            // dirty output buffer: compute_into must fully overwrite it
            let mut out = Matrix::full(q.rows(), v.cols(), f32::NAN);
            m.compute_into(&AttnInputs::new(&q, &k, &v).with_seed(9), &mut out, &mut scratch);
            assert_eq!(out.max_abs_diff(&legacy), 0.0, "{} diverged", m.name());
        }
    }

    #[test]
    fn cross_shape_capability_is_honoured() {
        let (q, k, v) = toy();
        let q_small = q.gather_rows(&[0, 5, 9]); // 3 decode queries
        for m in registry(16) {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                m.compute(&q_small, &k, &v, None, &mut Rng::new(2))
            }));
            if m.supports_cross_shape() {
                let out = result.unwrap_or_else(|_| panic!("{} rejected cross shape", m.name()));
                assert_eq!(out.shape(), (3, v.cols()), "{}", m.name());
                assert!(out.all_finite(), "{}", m.name());
            } else {
                assert!(result.is_err(), "{} must reject cross shape", m.name());
            }
        }
    }

    #[test]
    #[should_panic]
    fn compute_into_rejects_wrong_output_shape() {
        let (q, k, v) = toy();
        let mut out = Matrix::zeros(q.rows(), v.cols() + 1);
        Standard.compute_into(&AttnInputs::new(&q, &k, &v), &mut out, &mut AttnScratch::new());
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("skeinformer", 8).is_some());
        assert!(by_name("nope", 8).is_none());
    }
}
