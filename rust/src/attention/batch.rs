//! Batched multi-head attention engine: run any [`AttentionMethod`] over a
//! `B × H` grid of head slices, dispatching heads across workers.
//!
//! This is the execution path the serving coordinator and the throughput
//! benches use for the realistic workload shape — many sequences × many
//! heads — instead of the single-matrix `n×p` call.
//!
//! **Shape conventions.** Inputs are [`BatchTensor`]s of shape
//! `[batch, heads, seq, head_dim]` (head slices contiguous, so per-head
//! extraction is one memcpy).  Padding masks are per *sequence*: a
//! `(batch, seq)` [`Matrix`] whose row `b` is the 0/1 key mask shared by
//! all heads of sequence `b`.
//!
//! **RNG-stream derivation rule.** Head `(b, h)` draws its randomness from
//! `Rng::new(seed ^ head_index)` with `head_index = b * heads + h`.  The
//! stream depends only on the grid position and the caller's seed — never
//! on the worker schedule — so the output is **bitwise identical for every
//! worker count** (verified by the conformance suite at workers `1` vs
//! [`pool::worker_count`]).
//!
//! ```
//! use skeinformer::attention::{BatchedAttention, Standard};
//! use skeinformer::tensor::BatchTensor;
//!
//! let q = BatchTensor::from_fn(2, 4, 32, 8, |b, h, i, j| {
//!     ((b + h * 3 + i * 5 + j) as f32 * 0.1).sin()
//! });
//! let out = BatchedAttention::new().run(&Standard, &q, &q, &q, None, 7);
//! assert_eq!(out.shape(), (2, 4, 32, 8));
//! ```

use super::AttentionMethod;
use crate::pool;
use crate::rng::Rng;
use crate::tensor::{BatchTensor, Matrix};

/// The shape of a batched multi-head workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadSpec {
    /// Number of sequences in the batch.
    pub batch: usize,
    /// Attention heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
}

impl HeadSpec {
    pub fn new(batch: usize, heads: usize, seq: usize, head_dim: usize) -> Self {
        Self { batch, heads, seq, head_dim }
    }

    /// The spec of an existing tensor.
    pub fn of(t: &BatchTensor) -> Self {
        let (batch, heads, seq, head_dim) = t.shape();
        Self { batch, heads, seq, head_dim }
    }

    /// Head slices in the grid (`batch * heads`).
    pub fn head_count(&self) -> usize {
        self.batch * self.heads
    }

    /// Total f32 elements per tensor of this shape.
    pub fn elems(&self) -> usize {
        self.batch * self.heads * self.seq * self.head_dim
    }

    /// Flat grid index of head `(b, h)` — the value XOR'd into the seed.
    pub fn head_index(&self, b: usize, h: usize) -> u64 {
        (b * self.heads + h) as u64
    }

    /// An all-zeros tensor of this shape.
    pub fn zeros(&self) -> BatchTensor {
        BatchTensor::zeros(self.batch, self.heads, self.seq, self.head_dim)
    }

    pub fn matches(&self, t: &BatchTensor) -> bool {
        *self == Self::of(t)
    }
}

/// Runs an [`AttentionMethod`] over every head of a batched workload,
/// dispatching heads across workers via [`pool::parallel_map_workers`].
///
/// The default worker cap is [`pool::worker_count`]; `with_workers` pins it
/// (the worker-invariance tests pin 1 vs N and assert bitwise equality).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchedAttention {
    workers: Option<usize>,
}

impl BatchedAttention {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin the worker cap for head dispatch.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// The effective worker cap.
    pub fn workers(&self) -> usize {
        self.workers.unwrap_or_else(pool::worker_count)
    }

    /// Compute attention for every head of the grid.
    ///
    /// `q`, `k`, `v` must share one shape; `masks`, when present, is
    /// `(batch, seq)` with row `b` the 0/1 key mask for sequence `b`.
    /// Randomness follows the module-level derivation rule, so the result
    /// is a pure function of `(method, inputs, seed)`.
    pub fn run(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        k: &BatchTensor,
        v: &BatchTensor,
        masks: Option<&Matrix>,
        seed: u64,
    ) -> BatchTensor {
        let spec = HeadSpec::of(q);
        assert!(spec.matches(k), "Q/K batch shapes differ: {:?} vs {:?}", q, k);
        assert!(spec.matches(v), "Q/V batch shapes differ: {:?} vs {:?}", q, v);
        if let Some(m) = masks {
            assert_eq!(
                m.shape(),
                (spec.batch, spec.seq),
                "mask must be (batch, seq)"
            );
        }

        let grid: Vec<(usize, usize)> = (0..spec.batch)
            .flat_map(|b| (0..spec.heads).map(move |h| (b, h)))
            .collect();
        let outs = pool::parallel_map_workers(&grid, self.workers(), |&(b, h)| {
            let mut rng = Rng::new(seed ^ spec.head_index(b, h));
            let qm = q.head_matrix(b, h);
            let km = k.head_matrix(b, h);
            let vm = v.head_matrix(b, h);
            let mask_row = masks.map(|m| m.row(b));
            method.compute(&qm, &km, &vm, mask_row, &mut rng)
        });

        let mut out = spec.zeros();
        for (&(b, h), m) in grid.iter().zip(&outs) {
            out.set_head(b, h, m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{Skeinformer, Standard};

    fn toy_qkv(spec: HeadSpec) -> (BatchTensor, BatchTensor, BatchTensor) {
        let mk = |salt: usize| {
            let mut t = spec.zeros();
            let mut rng = Rng::new(900 + salt as u64);
            rng.fill_normal(t.data_mut());
            t
        };
        (mk(0), mk(1), mk(2))
    }

    #[test]
    fn batched_standard_matches_per_head_exact() {
        let spec = HeadSpec::new(2, 3, 16, 4);
        let (q, k, v) = toy_qkv(spec);
        let out = BatchedAttention::new().run(&Standard, &q, &k, &v, None, 0);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let want = Standard::exact(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                );
                assert_eq!(out.head_matrix(b, h).max_abs_diff(&want), 0.0, "head ({b},{h})");
            }
        }
    }

    #[test]
    fn rng_streams_follow_the_derivation_rule() {
        let spec = HeadSpec::new(2, 2, 24, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let seed = 41u64;
        let out = BatchedAttention::new().run(&skein, &q, &k, &v, None, seed);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let mut rng = Rng::new(seed ^ spec.head_index(b, h));
                let want = skein.compute(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                    &mut rng,
                );
                assert_eq!(
                    out.head_matrix(b, h).max_abs_diff(&want),
                    0.0,
                    "head ({b},{h}) deviates from documented stream"
                );
            }
        }
    }

    #[test]
    fn per_sequence_masks_apply_to_the_right_rows() {
        let spec = HeadSpec::new(2, 2, 12, 4);
        let (q, k, v) = toy_qkv(spec);
        // sequence 0 fully valid; sequence 1 padded after position 8
        let masks = Matrix::from_fn(2, 12, |b, i| {
            if b == 1 && i >= 8 {
                0.0
            } else {
                1.0
            }
        });
        let out = BatchedAttention::new().run(&Standard, &q, &k, &v, Some(&masks), 0);
        for h in 0..spec.heads {
            let want0 = Standard::exact(
                &q.head_matrix(0, h),
                &k.head_matrix(0, h),
                &v.head_matrix(0, h),
                None,
            );
            assert_eq!(out.head_matrix(0, h).max_abs_diff(&want0), 0.0);
            let mask1: Vec<f32> = masks.row(1).to_vec();
            let want1 = Standard::exact(
                &q.head_matrix(1, h),
                &k.head_matrix(1, h),
                &v.head_matrix(1, h),
                Some(&mask1),
            );
            assert_eq!(out.head_matrix(1, h).max_abs_diff(&want1), 0.0);
        }
    }

    #[test]
    fn worker_cap_does_not_change_results() {
        let spec = HeadSpec::new(3, 4, 32, 8);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(12);
        let one = BatchedAttention::new().with_workers(1).run(&skein, &q, &k, &v, None, 5);
        let many = BatchedAttention::new()
            .with_workers(pool::worker_count())
            .run(&skein, &q, &k, &v, None, 5);
        assert_eq!(one.max_abs_diff(&many), 0.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let q = BatchTensor::zeros(1, 2, 8, 4);
        let k = BatchTensor::zeros(1, 2, 8, 4);
        let v = BatchTensor::zeros(1, 2, 16, 4);
        let _ = BatchedAttention::new().run(&Standard, &q, &k, &v, None, 0);
    }
}
