//! Batched multi-head attention engine: run any [`AttentionMethod`] over a
//! `B × H` grid of head slices, dispatching heads across the persistent
//! worker pool.
//!
//! This is the execution path the serving coordinator and the throughput
//! benches use for the realistic workload shape — many sequences × many
//! heads — instead of the single-matrix `n×p` call.
//!
//! **Shape conventions.** Inputs are [`BatchTensor`]s of shape
//! `[batch, heads, seq, head_dim]` (head slices contiguous, so per-head
//! extraction is one memcpy — into a per-worker scratch buffer reused
//! across heads, so steady state allocates nothing).  Slab-backed
//! tensors ([`BatchTensor::from_slabs`]) work identically: the engine
//! reads each client slab in place.  Padding masks are per *sequence*: a
//! `(batch, seq)` [`Matrix`] whose row `b` is the 0/1 key mask shared by
//! all heads of sequence `b`.
//!
//! **Zero-allocation hot loop.** Heads execute through the v2
//! [`AttentionMethod::compute_into`] API: per-head Q/K/V extraction, the
//! per-head output staging buffer, and every method temporary
//! ([`AttnScratch`]) come from the worker pool's thread-local recycled
//! stash, and each head's result is written directly into the output
//! tensor's slice ([`BatchedAttention::run_into`]).  After the first
//! batch warms each worker, the per-head loop performs no
//! `seq × head_dim`-scaled heap allocation; the O(d) index/key draws
//! inside the Gumbel sampler are scratch-recycled too
//! (`Rng::weighted_without_replacement_into`), so what remains is O(B·H)
//! dispatch bookkeeping per *call* (task boxes, the grid list).
//!
//! **RNG-stream derivation rule.** Head `(b, h)` draws its randomness from
//! `Rng::new(seed ^ head_index)` with `head_index = b * heads + h`.  The
//! stream depends only on the grid position and the caller's seed — never
//! on the worker schedule — so the output is **bitwise identical for every
//! worker count** (verified by the conformance suite at workers `1` vs
//! [`pool::worker_count`]).
//!
//! **Inner-kernel planning.** When the head grid alone saturates the pool
//! (`min(head_count, worker cap) ≥ pool size`), each head's inner matmuls
//! are forced single-threaded via
//! [`with_default_plan`](crate::tensor::with_default_plan) — parallelism
//! is already exhausted at the head level, and letting every head also
//! spawn row-block tasks oversubscribes the pool (~10–20% throughput loss
//! measured at 16×8).  Under-saturated grids keep `Auto`, so a 1×1 grid
//! at long `seq` still parallelises inside the head.  Plans never change
//! results, only threading.
//!
//! ```
//! use skeinformer::attention::{BatchedAttention, Standard};
//! use skeinformer::tensor::BatchTensor;
//!
//! let q = BatchTensor::from_fn(2, 4, 32, 8, |b, h, i, j| {
//!     ((b + h * 3 + i * 5 + j) as f32 * 0.1).sin()
//! });
//! let out = BatchedAttention::new().run(&Standard, &q, &q, &q, None, 7);
//! assert_eq!(out.shape(), (2, 4, 32, 8));
//! ```

use super::{AttentionMethod, AttnInputs, AttnScratch};
use crate::pool;
use crate::tensor::{with_default_plan, BatchTensor, Matrix, MatmulPlan};

/// The shape of a batched multi-head workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadSpec {
    /// Number of sequences in the batch.
    pub batch: usize,
    /// Attention heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
}

impl HeadSpec {
    pub fn new(batch: usize, heads: usize, seq: usize, head_dim: usize) -> Self {
        Self { batch, heads, seq, head_dim }
    }

    /// The spec of an existing tensor.
    pub fn of(t: &BatchTensor) -> Self {
        let (batch, heads, seq, head_dim) = t.shape();
        Self { batch, heads, seq, head_dim }
    }

    /// Head slices in the grid (`batch * heads`).
    pub fn head_count(&self) -> usize {
        self.batch * self.heads
    }

    /// Total f32 elements per tensor of this shape.
    pub fn elems(&self) -> usize {
        self.batch * self.heads * self.seq * self.head_dim
    }

    /// Flat grid index of head `(b, h)` — the value XOR'd into the seed.
    pub fn head_index(&self, b: usize, h: usize) -> u64 {
        (b * self.heads + h) as u64
    }

    /// An all-zeros tensor of this shape.
    pub fn zeros(&self) -> BatchTensor {
        BatchTensor::zeros(self.batch, self.heads, self.seq, self.head_dim)
    }

    pub fn matches(&self, t: &BatchTensor) -> bool {
        *self == Self::of(t)
    }
}

/// Runs an [`AttentionMethod`] over every head of a batched workload,
/// dispatching heads across workers via [`pool::parallel_map_workers`].
///
/// The default worker cap is [`pool::pool_size`] — the persistent pool's
/// thread count, so a `--pool-size` override propagates to head dispatch;
/// `with_workers` pins it (the worker-invariance tests pin 1 vs N and
/// assert bitwise equality).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchedAttention {
    workers: Option<usize>,
}

impl BatchedAttention {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin the worker cap for head dispatch.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// The effective worker cap.
    pub fn workers(&self) -> usize {
        self.workers.unwrap_or_else(pool::pool_size)
    }

    /// Compute attention for every head of the grid.
    ///
    /// `q`, `k`, `v` must share one shape; `masks`, when present, is
    /// `(batch, seq)` with row `b` the 0/1 key mask for sequence `b`.
    /// Randomness follows the module-level derivation rule, so the result
    /// is a pure function of `(method, inputs, seed)`.
    pub fn run(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        k: &BatchTensor,
        v: &BatchTensor,
        masks: Option<&Matrix>,
        seed: u64,
    ) -> BatchTensor {
        let mut out = HeadSpec::of(q).zeros();
        self.run_into(method, q, k, v, masks, seed, &mut out);
        out
    }

    /// [`run`](Self::run) into a caller-provided output tensor (owned
    /// storage, same shape as `q`; fully overwritten) — the
    /// zero-allocation serving path.  Each worker computes its heads
    /// through [`AttentionMethod::compute_into`] with per-worker recycled
    /// scratch and writes the result directly into `out`'s head slice, so
    /// after warmup the B×H hot loop performs no heap allocation (the
    /// only steady-state allocations left are the per-call dispatch
    /// bookkeeping — O(B·H) task records, not O(elements) buffers).
    #[allow(clippy::too_many_arguments)]
    pub fn run_into(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        k: &BatchTensor,
        v: &BatchTensor,
        masks: Option<&Matrix>,
        seed: u64,
        out: &mut BatchTensor,
    ) {
        let spec = HeadSpec::of(q);
        assert!(spec.matches(k), "Q/K batch shapes differ: {:?} vs {:?}", q, k);
        assert!(spec.matches(v), "Q/V batch shapes differ: {:?} vs {:?}", q, v);
        // tensor-backed K/V: one contiguous memcpy per head slice
        let fill = |b: usize, h: usize, km: &mut Matrix, vm: &mut Matrix| {
            km.data_mut().copy_from_slice(k.head(b, h));
            vm.data_mut().copy_from_slice(v.head(b, h));
        };
        let seed_of = move |b: usize, h: usize| seed ^ spec.head_index(b, h);
        self.dispatch_heads(method, q, spec.seq, &fill, masks, &seed_of, out);
    }

    /// [`run_into`](Self::run_into) with the K/V bytes *gathered* per
    /// head instead of read from tensors: `fill_kv(b, h, k_out, v_out)`
    /// must fully overwrite the two pre-shaped `(kv_rows, head_dim)`
    /// scratch matrices with sequence `b`, head `h`'s keys and values
    /// (e.g. `StreamChain::gather_head_into` from shared KV-cache
    /// blocks — the batch-dedupe serving path).  Everything else — seed
    /// derivation, per-worker scratch, inner-plan policy, in-place head
    /// writes — is the tensor path, so when `fill_kv` writes the same
    /// bytes a tensor would hold, the output is **bitwise identical** to
    /// [`run_into`](Self::run_into).  `fill_kv` runs concurrently across
    /// heads and must only read shared state.
    #[allow(clippy::too_many_arguments)]
    pub fn run_gather_into(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        kv_rows: usize,
        fill_kv: &(dyn Fn(usize, usize, &mut Matrix, &mut Matrix) + Sync),
        masks: Option<&Matrix>,
        seed: u64,
        out: &mut BatchTensor,
    ) {
        assert!(kv_rows > 0, "gathered K/V must have rows");
        let spec = HeadSpec::of(q);
        let seed_of = move |b: usize, h: usize| seed ^ spec.head_index(b, h);
        self.dispatch_heads(method, q, kv_rows, fill_kv, masks, &seed_of, out);
    }

    /// [`run_into`](Self::run_into) with **explicit per-sequence seeds
    /// and a head offset** — the shard scatter path.  Head `(b, h)`
    /// draws from `Rng::new(seeds[b] ^ (head_offset + h))`: the batch
    /// position `b` does not participate, so how requests are packed
    /// into shard-side batches never changes a head's RNG stream, and a
    /// shard computing the head slice `[lo, lo + heads)` of a request
    /// whose single-sequence seed is `s` reproduces exactly the streams
    /// the full-width engine derives for those heads (`s ^ (lo + h)` at
    /// batch position 0) — the placement-invariance the coordinator's
    /// bitwise gather rests on.
    #[allow(clippy::too_many_arguments)]
    pub fn run_seeded_into(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        k: &BatchTensor,
        v: &BatchTensor,
        masks: Option<&Matrix>,
        seeds: &[u64],
        head_offset: usize,
        out: &mut BatchTensor,
    ) {
        let spec = HeadSpec::of(q);
        assert!(spec.matches(k), "Q/K batch shapes differ: {:?} vs {:?}", q, k);
        assert!(spec.matches(v), "Q/V batch shapes differ: {:?} vs {:?}", q, v);
        assert_eq!(seeds.len(), spec.batch, "one seed per sequence");
        let fill = |b: usize, h: usize, km: &mut Matrix, vm: &mut Matrix| {
            km.data_mut().copy_from_slice(k.head(b, h));
            vm.data_mut().copy_from_slice(v.head(b, h));
        };
        let seed_of = move |b: usize, h: usize| seeds[b] ^ (head_offset + h) as u64;
        self.dispatch_heads(method, q, spec.seq, &fill, masks, &seed_of, out);
    }

    /// [`run_seeded_into`](Self::run_seeded_into) with gathered K/V —
    /// the seeded twin of [`run_gather_into`](Self::run_gather_into).
    #[allow(clippy::too_many_arguments)]
    pub fn run_gather_seeded_into(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        kv_rows: usize,
        fill_kv: &(dyn Fn(usize, usize, &mut Matrix, &mut Matrix) + Sync),
        masks: Option<&Matrix>,
        seeds: &[u64],
        head_offset: usize,
        out: &mut BatchTensor,
    ) {
        assert!(kv_rows > 0, "gathered K/V must have rows");
        let spec = HeadSpec::of(q);
        assert_eq!(seeds.len(), spec.batch, "one seed per sequence");
        let seed_of = move |b: usize, h: usize| seeds[b] ^ (head_offset + h) as u64;
        self.dispatch_heads(method, q, kv_rows, fill_kv, masks, &seed_of, out);
    }

    /// The shared B×H dispatcher behind [`run_into`](Self::run_into) and
    /// [`run_gather_into`](Self::run_gather_into): fan heads over the
    /// pool, extract Q from the tensor and K/V through `fill_kv`, derive
    /// head `(b, h)`'s RNG stream through `seed_of`, and write each
    /// head's result in place.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_heads(
        &self,
        method: &dyn AttentionMethod,
        q: &BatchTensor,
        kv_rows: usize,
        fill_kv: &(dyn Fn(usize, usize, &mut Matrix, &mut Matrix) + Sync),
        masks: Option<&Matrix>,
        seed_of: &(dyn Fn(usize, usize) -> u64 + Sync),
        out: &mut BatchTensor,
    ) {
        let spec = HeadSpec::of(q);
        assert!(spec.matches(out), "output shape differs: {:?} vs {:?}", q, out);
        if let Some(m) = masks {
            assert_eq!(
                m.shape(),
                (spec.batch, kv_rows),
                "mask must be (batch, kv_rows)"
            );
        }

        let grid: Vec<(usize, usize)> = (0..spec.batch)
            .flat_map(|b| (0..spec.heads).map(move |h| (b, h)))
            .collect();
        // The grid saturates the pool when the heads running concurrently
        // already cover every pool thread; inner matmuls then go
        // single-threaded instead of oversubscribing (module docs).
        let workers = self.workers();
        let inner_plan = if grid.len().min(workers) >= pool::pool_size() {
            MatmulPlan::SingleThread
        } else {
            MatmulPlan::Auto
        };
        let head_elems = spec.seq * spec.head_dim;
        let kv_elems = kv_rows * spec.head_dim;
        // Workers write disjoint head slices of `out` in place.  SAFETY:
        // head (b, h) owns exactly out[head_index * head_elems ..][..head_elems]
        // (owned storage is one contiguous [b][h][n][d] buffer), each grid
        // entry appears once, and parallel_map_workers does not return
        // until every task completed — so writes never alias and never
        // outlive the borrow.
        let out_ptr = pool::SendPtr(out.data_mut().as_mut_ptr());
        pool::parallel_map_workers(&grid, workers, |&(b, h)| {
            let out_ptr = out_ptr; // force whole-struct capture
            let head_seed = seed_of(b, h);
            // Per-head buffers come from per-worker scratch reused across
            // heads (and across engine calls, since the pool threads are
            // persistent) — no steady-state allocation.
            let shaped = |rows: usize, elems: usize| {
                let mut buf = pool::take_scratch(elems);
                buf.resize(elems, 0.0);
                Matrix::from_vec(rows, spec.head_dim, buf)
            };
            let qm = {
                let mut buf = pool::take_scratch(head_elems);
                buf.extend_from_slice(q.head(b, h));
                Matrix::from_vec(spec.seq, spec.head_dim, buf)
            };
            let mut km = shaped(kv_rows, kv_elems);
            let mut vm = shaped(kv_rows, kv_elems);
            fill_kv(b, h, &mut km, &mut vm);
            let mask_row = masks.map(|m| m.row(b));
            let mut head_out = shaped(spec.seq, head_elems);
            let mut scratch = AttnScratch::new();
            let inputs = AttnInputs::new(&qm, &km, &vm).with_mask(mask_row).with_seed(head_seed);
            with_default_plan(inner_plan, || {
                method.compute_into(&inputs, &mut head_out, &mut scratch)
            });
            let offset = (b * spec.heads + h) * head_elems;
            unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(offset), head_elems)
                    .copy_from_slice(head_out.data());
            }
            pool::recycle_scratch(head_out.into_vec());
            pool::recycle_scratch(qm.into_vec());
            pool::recycle_scratch(km.into_vec());
            pool::recycle_scratch(vm.into_vec());
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{Skeinformer, Standard};
    use crate::rng::Rng;

    fn toy_qkv(spec: HeadSpec) -> (BatchTensor, BatchTensor, BatchTensor) {
        let mk = |salt: usize| {
            let mut t = spec.zeros();
            let mut rng = Rng::new(900 + salt as u64);
            rng.fill_normal(t.data_mut());
            t
        };
        (mk(0), mk(1), mk(2))
    }

    #[test]
    fn batched_standard_matches_per_head_exact() {
        let spec = HeadSpec::new(2, 3, 16, 4);
        let (q, k, v) = toy_qkv(spec);
        let out = BatchedAttention::new().run(&Standard, &q, &k, &v, None, 0);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let want = Standard::exact(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                );
                assert_eq!(out.head_matrix(b, h).max_abs_diff(&want), 0.0, "head ({b},{h})");
            }
        }
    }

    #[test]
    fn rng_streams_follow_the_derivation_rule() {
        let spec = HeadSpec::new(2, 2, 24, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let seed = 41u64;
        let out = BatchedAttention::new().run(&skein, &q, &k, &v, None, seed);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let mut rng = Rng::new(seed ^ spec.head_index(b, h));
                let want = skein.compute(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                    &mut rng,
                );
                assert_eq!(
                    out.head_matrix(b, h).max_abs_diff(&want),
                    0.0,
                    "head ({b},{h}) deviates from documented stream"
                );
            }
        }
    }

    #[test]
    fn per_sequence_masks_apply_to_the_right_rows() {
        let spec = HeadSpec::new(2, 2, 12, 4);
        let (q, k, v) = toy_qkv(spec);
        // sequence 0 fully valid; sequence 1 padded after position 8
        let masks = Matrix::from_fn(2, 12, |b, i| {
            if b == 1 && i >= 8 {
                0.0
            } else {
                1.0
            }
        });
        let out = BatchedAttention::new().run(&Standard, &q, &k, &v, Some(&masks), 0);
        for h in 0..spec.heads {
            let want0 = Standard::exact(
                &q.head_matrix(0, h),
                &k.head_matrix(0, h),
                &v.head_matrix(0, h),
                None,
            );
            assert_eq!(out.head_matrix(0, h).max_abs_diff(&want0), 0.0);
            let mask1: Vec<f32> = masks.row(1).to_vec();
            let want1 = Standard::exact(
                &q.head_matrix(1, h),
                &k.head_matrix(1, h),
                &v.head_matrix(1, h),
                Some(&mask1),
            );
            assert_eq!(out.head_matrix(1, h).max_abs_diff(&want1), 0.0);
        }
    }

    #[test]
    fn worker_cap_does_not_change_results() {
        let spec = HeadSpec::new(3, 4, 32, 8);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(12);
        let one = BatchedAttention::new().with_workers(1).run(&skein, &q, &k, &v, None, 5);
        let many = BatchedAttention::new()
            .with_workers(pool::worker_count())
            .run(&skein, &q, &k, &v, None, 5);
        assert_eq!(one.max_abs_diff(&many), 0.0);
    }

    #[test]
    fn slab_backed_inputs_match_owned_bitwise() {
        // zero-copy serving path: Arc-slab views must produce the exact
        // bytes the owned-Vec path does
        let spec = HeadSpec::new(3, 2, 24, 4);
        let (q, k, v) = toy_qkv(spec);
        let to_slabs = |t: &BatchTensor| {
            BatchTensor::from_slabs(
                spec.heads,
                spec.seq,
                spec.head_dim,
                (0..spec.batch)
                    .map(|b| std::sync::Arc::from(t.sequence(b).to_vec()))
                    .collect(),
            )
        };
        let (qs, ks, vs) = (to_slabs(&q), to_slabs(&k), to_slabs(&v));
        let skein = Skeinformer::new(8);
        let owned = BatchedAttention::new().run(&skein, &q, &k, &v, None, 9);
        let slab = BatchedAttention::new().run(&skein, &qs, &ks, &vs, None, 9);
        assert_eq!(owned.max_abs_diff(&slab), 0.0);
    }

    #[test]
    fn run_into_overwrites_dirty_output_bitwise() {
        let spec = HeadSpec::new(2, 3, 16, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let engine = BatchedAttention::new();
        let want = engine.run(&skein, &q, &k, &v, None, 3);
        let mut out = spec.zeros();
        out.data_mut().iter_mut().for_each(|x| *x = f32::NAN);
        engine.run_into(&skein, &q, &k, &v, None, 3, &mut out);
        assert_eq!(out.max_abs_diff(&want), 0.0);
        // reusing the same output tensor again must also be clean
        engine.run_into(&skein, &q, &k, &v, None, 3, &mut out);
        assert_eq!(out.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn run_gather_into_matches_tensor_path_bitwise() {
        // a fill_kv that writes the tensor bytes must reproduce run_into
        // exactly — the contract the batch-dedupe serving path relies on
        let spec = HeadSpec::new(3, 2, 16, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let engine = BatchedAttention::new();
        let want = engine.run(&skein, &q, &k, &v, None, 13);
        let fill = |b: usize, h: usize, km: &mut Matrix, vm: &mut Matrix| {
            km.data_mut().copy_from_slice(k.head(b, h));
            vm.data_mut().copy_from_slice(v.head(b, h));
        };
        let mut out = spec.zeros();
        out.data_mut().iter_mut().for_each(|x| *x = f32::NAN);
        engine.run_gather_into(&skein, &q, spec.seq, &fill, None, 13, &mut out);
        assert_eq!(out.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn seeded_head_slice_matches_full_run_bitwise() {
        // the shard placement-invariance contract: computing only heads
        // [lo, hi) of a single sequence with run_seeded_into(seeds=[s],
        // head_offset=lo) must reproduce the full-width run at seed s
        // exactly, because batch position 0 contributes nothing to the
        // derived streams
        let spec = HeadSpec::new(1, 4, 16, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let engine = BatchedAttention::new();
        let seed = 0xAB5E_u64;
        let full = engine.run(&skein, &q, &k, &v, None, seed);
        let (lo, hi) = (1, 3);
        let slice = |t: &BatchTensor| {
            let mut s = BatchTensor::zeros(1, hi - lo, spec.seq, spec.head_dim);
            for h in lo..hi {
                let src = t.head(0, h).to_vec();
                s.head_mut(0, h - lo).copy_from_slice(&src);
            }
            s
        };
        let (qs, ks, vs) = (slice(&q), slice(&k), slice(&v));
        let mut out = BatchTensor::zeros(1, hi - lo, spec.seq, spec.head_dim);
        engine.run_seeded_into(&skein, &qs, &ks, &vs, None, &[seed], lo, &mut out);
        for h in lo..hi {
            assert_eq!(
                out.head_matrix(0, h - lo).max_abs_diff(&full.head_matrix(0, h)),
                0.0,
                "sliced head {h} deviates from the full-width run"
            );
        }
    }

    #[test]
    fn seeded_batch_packing_does_not_change_results() {
        // two routed requests packed into one shard batch must equal
        // the two singleton runs — `b` never enters seed derivation
        let spec = HeadSpec::new(2, 3, 12, 4);
        let (q, k, v) = toy_qkv(spec);
        let skein = Skeinformer::new(8);
        let engine = BatchedAttention::new();
        let seeds = [11u64, 77u64];
        let mut packed = spec.zeros();
        engine.run_seeded_into(&skein, &q, &k, &v, None, &seeds, 1, &mut packed);
        for b in 0..2 {
            let single = |t: &BatchTensor| {
                let mut s = BatchTensor::zeros(1, spec.heads, spec.seq, spec.head_dim);
                s.data_mut().copy_from_slice(t.sequence(b));
                s
            };
            let (qs, ks, vs) = (single(&q), single(&k), single(&v));
            let mut solo = BatchTensor::zeros(1, spec.heads, spec.seq, spec.head_dim);
            engine.run_seeded_into(&skein, &qs, &ks, &vs, None, &seeds[b..=b], 1, &mut solo);
            assert_eq!(
                solo.data(),
                packed.sequence(b),
                "sequence {b} changed under batch packing"
            );
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let q = BatchTensor::zeros(1, 2, 8, 4);
        let k = BatchTensor::zeros(1, 2, 8, 4);
        let v = BatchTensor::zeros(1, 2, 16, 4);
        let _ = BatchedAttention::new().run(&Standard, &q, &k, &v, None, 0);
    }
}
