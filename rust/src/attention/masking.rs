//! Padding-mask helpers shared by the attention implementations (§4.4).
//!
//! A mask is a length-n slice of 0.0/1.0 weights over key positions.  The
//! helpers keep the convention in one place: masked keys get `-inf` scores
//! before a softmax, zeroed columns after it, and are excluded from
//! sampling probabilities.

use crate::tensor::Matrix;

/// Number of valid (un-padded) positions; at least 1 to avoid div-by-zero.
pub fn valid_count(mask: Option<&[f32]>, n: usize) -> f32 {
    match mask {
        None => n as f32,
        Some(m) => m.iter().filter(|x| **x > 0.0).count().max(1) as f32,
    }
}

/// Indices of valid positions (all of `0..n` when unmasked).
pub fn valid_indices(mask: Option<&[f32]>, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    valid_indices_into(mask, n, &mut out);
    out
}

/// [`valid_indices`] into a reused buffer (cleared first) — the
/// scratch-friendly variant the v2 hot paths use.
pub fn valid_indices_into(mask: Option<&[f32]>, n: usize, out: &mut Vec<usize>) {
    out.clear();
    match mask {
        None => out.extend(0..n),
        Some(m) => out.extend((0..n).filter(|&i| m[i] > 0.0)),
    }
}

/// Apply `-1e30` to masked-key columns of a raw score matrix, in place.
pub fn mask_score_columns(scores: &mut Matrix, mask: Option<&[f32]>) {
    let Some(m) = mask else { return };
    assert_eq!(m.len(), scores.cols());
    for i in 0..scores.rows() {
        let row = scores.row_mut(i);
        for (x, &w) in row.iter_mut().zip(m) {
            if w <= 0.0 {
                *x = -1e30;
            }
        }
    }
}

/// Zero masked columns of a (row-stochastic) matrix, in place — the §4.4
/// trick that makes padded columns unsampleable.
pub fn zero_masked_columns(probs: &mut Matrix, mask: Option<&[f32]>) {
    let Some(m) = mask else { return };
    assert_eq!(m.len(), probs.cols());
    for i in 0..probs.rows() {
        let row = probs.row_mut(i);
        for (x, &w) in row.iter_mut().zip(m) {
            if w <= 0.0 {
                *x = 0.0;
            }
        }
    }
}

/// Zero out per-index weights at masked positions.
pub fn mask_weights(weights: &mut [f32], mask: Option<&[f32]>) {
    let Some(m) = mask else { return };
    assert_eq!(m.len(), weights.len());
    for (w, &keep) in weights.iter_mut().zip(m) {
        if keep <= 0.0 {
            *w = 0.0;
        }
    }
}

/// Column sums of V restricted to valid rows: `1ᵀ V` over the mask.
pub fn masked_col_sums(v: &Matrix, mask: Option<&[f32]>) -> Vec<f32> {
    let mut out = vec![0.0f32; v.cols()];
    masked_col_sums_into(v, mask, &mut out);
    out
}

/// [`masked_col_sums`] into a reused buffer (fully overwritten; dirty
/// reuse is fine) — the scratch-friendly variant.  `out` must hold
/// exactly `v.cols()` elements.
pub fn masked_col_sums_into(v: &Matrix, mask: Option<&[f32]>, out: &mut [f32]) {
    assert_eq!(out.len(), v.cols(), "masked_col_sums_into length mismatch");
    out.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..v.rows() {
        let keep = mask.map_or(1.0, |m| m[i]);
        if keep > 0.0 {
            for (o, &x) in out.iter_mut().zip(v.row(i)) {
                *o += x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_count_and_indices() {
        let mask = [1.0, 0.0, 1.0, 1.0];
        assert_eq!(valid_count(Some(&mask), 4), 3.0);
        assert_eq!(valid_indices(Some(&mask), 4), vec![0, 2, 3]);
        assert_eq!(valid_count(None, 4), 4.0);
        assert_eq!(valid_indices(None, 3), vec![0, 1, 2]);
    }

    #[test]
    fn fully_masked_count_clamps_to_one() {
        let mask = [0.0; 4];
        assert_eq!(valid_count(Some(&mask), 4), 1.0);
    }

    #[test]
    fn score_and_prob_masking() {
        let mask = [1.0, 0.0];
        let mut s = Matrix::full(2, 2, 1.0);
        mask_score_columns(&mut s, Some(&mask));
        assert_eq!(s.get(0, 0), 1.0);
        assert!(s.get(0, 1) < -1e29);

        let mut p = Matrix::full(2, 2, 0.5);
        zero_masked_columns(&mut p, Some(&mask));
        assert_eq!(p.get(1, 1), 0.0);
        assert_eq!(p.get(1, 0), 0.5);
    }

    #[test]
    fn masked_col_sums_skips_padded_rows() {
        let v = Matrix::from_rows(&[vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]]);
        let mask = [1.0, 0.0, 1.0];
        assert_eq!(masked_col_sums(&v, Some(&mask)), vec![101.0, 202.0]);
        assert_eq!(masked_col_sums(&v, None), vec![111.0, 222.0]);
    }

    #[test]
    fn into_variants_reset_reused_buffers() {
        let v = Matrix::from_rows(&[vec![1.0, 2.0], vec![10.0, 20.0]]);
        let mut sums = vec![9.0f32, 9.0]; // dirty reuse
        masked_col_sums_into(&v, None, &mut sums);
        assert_eq!(sums, vec![11.0, 22.0]);

        let mask = [1.0, 0.0, 1.0, 1.0];
        let mut idx = vec![7usize; 3]; // dirty reuse
        valid_indices_into(Some(&mask), 4, &mut idx);
        assert_eq!(idx, vec![0, 2, 3]);
    }
}
