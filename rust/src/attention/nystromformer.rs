//! Nyströmformer (Xiong et al. 2021) — landmark-based Nyström approximation
//! of the softmax score matrix:
//!
//! `B ≈ softmax(Q K̃ᵀ/√p) · pinv(softmax(Q̃ K̃ᵀ/√p)) · softmax(Q̃ Kᵀ/√p)`
//!
//! with landmarks Q̃, K̃ from segment means and the pseudo-inverse computed
//! by the same Newton–Schulz iteration the published model uses.

use super::{check_inputs, masking, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_nt, scale_inplace, softmax_rows, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct Nystromformer {
    /// Number of landmarks.
    pub landmarks: usize,
    /// Newton–Schulz iterations for the pseudo-inverse.
    pub pinv_iters: usize,
}

impl Nystromformer {
    pub fn new(landmarks: usize) -> Self {
        Self { landmarks, pinv_iters: 6 }
    }

    /// Segment-mean landmarks: average consecutive chunks of rows.
    fn segment_means(x: &Matrix, m: usize) -> Matrix {
        let n = x.rows();
        let m = m.min(n);
        let seg = n / m;
        let mut out = Matrix::zeros(m, x.cols());
        for s in 0..m {
            let start = s * seg;
            let end = if s == m - 1 { n } else { start + seg };
            let count = (end - start) as f32;
            for i in start..end {
                for (o, &v) in out.row_mut(s).iter_mut().zip(x.row(i)) {
                    *o += v;
                }
            }
            out.row_mut(s).iter_mut().for_each(|v| *v /= count);
        }
        out
    }

    /// Newton–Schulz pseudo-inverse (the published Nystromformer recipe):
    /// `Z₀ = Aᵀ / (‖A‖₁ ‖A‖∞)`, then
    /// `Z ← ¼ Z (13 I − A Z (15 I − A Z (7 I − A Z)))`.
    pub fn newton_pinv(a: &Matrix, iters: usize) -> Matrix {
        let n = a.rows();
        assert_eq!(n, a.cols(), "pinv expects square");
        let norm1 = (0..n)
            .map(|j| (0..n).map(|i| a.get(i, j).abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let norminf = (0..n)
            .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let mut z = a.transpose();
        scale_inplace(&mut z, 1.0 / (norm1 * norminf).max(1e-30));
        let ident = Matrix::eye(n);
        for _ in 0..iters {
            let az = matmul(a, &z);
            // t1 = 7I − AZ
            let mut t1 = crate::tensor::sub(&ident, &az);
            scale_inplace(&mut t1, 1.0); // readability: t1 = I − AZ
            let mut seven = ident.clone();
            scale_inplace(&mut seven, 7.0);
            let t1 = crate::tensor::sub(&seven, &az);
            // t2 = 15I − AZ·t1
            let mut fifteen = ident.clone();
            scale_inplace(&mut fifteen, 15.0);
            let t2 = crate::tensor::sub(&fifteen, &matmul(&az, &t1));
            // t3 = 13I − AZ·t2
            let mut thirteen = ident.clone();
            scale_inplace(&mut thirteen, 13.0);
            let t3 = crate::tensor::sub(&thirteen, &matmul(&az, &t2));
            z = matmul(&z, &t3);
            scale_inplace(&mut z, 0.25);
        }
        z
    }
}

impl AttentionMethod for Nystromformer {
    fn name(&self) -> &'static str {
        "nystromformer"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        _rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let p = q.cols() as f32;
        let scale = 1.0 / p.sqrt();
        let q_land = Self::segment_means(q, self.landmarks);
        let k_land = Self::segment_means(k, self.landmarks);

        // F1 = softmax(Q K̃ᵀ)
        let mut f1 = matmul_nt(q, &k_land);
        scale_inplace(&mut f1, scale);
        softmax_rows(&mut f1);
        // A2 = softmax(Q̃ K̃ᵀ)
        let mut a2 = matmul_nt(&q_land, &k_land);
        scale_inplace(&mut a2, scale);
        softmax_rows(&mut a2);
        // F3 = softmax(Q̃ Kᵀ) with padding mask on keys
        let mut f3 = matmul_nt(&q_land, k);
        scale_inplace(&mut f3, scale);
        masking::mask_score_columns(&mut f3, mask);
        softmax_rows(&mut f3);

        let pinv = Self::newton_pinv(&a2, self.pinv_iters);
        matmul(&f1, &matmul(&pinv, &matmul(&f3, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::spectral_norm_diff;

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn newton_pinv_inverts_well_conditioned() {
        // a diagonally-dominant row-stochastic-ish matrix
        let n = 8;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 0.8 } else { 0.2 / (n - 1) as f32 });
        let z = Nystromformer::newton_pinv(&a, 12);
        let prod = matmul(&a, &z);
        let eye = Matrix::eye(n);
        assert!(prod.max_abs_diff(&eye) < 1e-2, "AZ far from I");
    }

    #[test]
    fn segment_means_average_chunks() {
        let x = Matrix::from_fn(8, 2, |i, _| i as f32);
        let m = Nystromformer::segment_means(&x, 4);
        assert_eq!(m.rows(), 4);
        assert!((m.get(0, 0) - 0.5).abs() < 1e-6);
        assert!((m.get(3, 0) - 6.5).abs() < 1e-6);
    }

    #[test]
    fn more_landmarks_reduce_error() {
        let (q, k, v) = qkv(128, 8, 1, 1.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let err = |m: usize| {
            spectral_norm_diff(
                &Nystromformer::new(m).compute(&q, &k, &v, None, &mut Rng::new(0)),
                &exact,
            )
        };
        assert!(err(64) < err(4), "landmarks 64 {} vs 4 {}", err(64), err(4));
    }

    #[test]
    fn near_exact_with_full_landmarks_on_smooth_inputs() {
        // Landmarks == n on smooth inputs: Nyström becomes near-exact.
        let n = 32;
        let (q, k, v) = qkv(n, 8, 3, 0.4);
        let exact = Standard::exact(&q, &k, &v, None);
        let out = Nystromformer::new(n).compute(&q, &k, &v, None, &mut Rng::new(0));
        let rel = spectral_norm_diff(&out, &exact) / crate::tensor::spectral_norm(&exact);
        assert!(rel < 0.25, "rel err {rel}");
    }
}
