//! Nyströmformer (Xiong et al. 2021) — landmark-based Nyström approximation
//! of the softmax score matrix:
//!
//! `B ≈ softmax(Q K̃ᵀ/√p) · pinv(softmax(Q̃ K̃ᵀ/√p)) · softmax(Q̃ Kᵀ/√p)`
//!
//! with landmarks Q̃, K̃ from segment means and the pseudo-inverse computed
//! by the same Newton–Schulz iteration the published model uses.

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    RecomputeSession, SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_into, matmul_nt_into, scale_inplace, softmax_rows, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct Nystromformer {
    /// Number of landmarks.
    pub landmarks: usize,
    /// Newton–Schulz iterations for the pseudo-inverse.
    pub pinv_iters: usize,
}

impl Nystromformer {
    pub fn new(landmarks: usize) -> Self {
        Self { landmarks, pinv_iters: 6 }
    }

    /// Segment-mean landmarks: average consecutive chunks of rows, into a
    /// zero-filled `(m.min(x.rows()), x.cols())` output.
    fn segment_means_into(x: &Matrix, m: usize, out: &mut Matrix) {
        let n = x.rows();
        let m = m.min(n);
        assert_eq!(out.shape(), (m, x.cols()), "segment_means_into shape mismatch");
        let seg = n / m;
        for s in 0..m {
            let start = s * seg;
            let end = if s == m - 1 { n } else { start + seg };
            let count = (end - start) as f32;
            for i in start..end {
                for (o, &v) in out.row_mut(s).iter_mut().zip(x.row(i)) {
                    *o += v;
                }
            }
            out.row_mut(s).iter_mut().for_each(|v| *v /= count);
        }
    }

    /// Allocating convenience over
    /// [`segment_means_into`](Self::segment_means_into).
    #[cfg_attr(not(test), allow(dead_code))]
    fn segment_means(x: &Matrix, m: usize) -> Matrix {
        let mut out = Matrix::zeros(m.min(x.rows()), x.cols());
        Self::segment_means_into(x, m, &mut out);
        out
    }

    /// Newton–Schulz pseudo-inverse (the published Nystromformer recipe):
    /// `Z₀ = Aᵀ / (‖A‖₁ ‖A‖∞)`, then
    /// `Z ← ¼ Z (13 I − A Z (15 I − A Z (7 I − A Z)))`.
    pub fn newton_pinv(a: &Matrix, iters: usize) -> Matrix {
        let n = a.rows();
        assert_eq!(n, a.cols(), "pinv expects square");
        let norm1 = (0..n)
            .map(|j| a.col_iter(j).map(f32::abs).sum::<f32>())
            .fold(0.0f32, f32::max);
        let norminf = (0..n)
            .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
            .fold(0.0f32, f32::max);
        let mut z = a.transpose();
        scale_inplace(&mut z, 1.0 / (norm1 * norminf).max(1e-30));
        let ident = Matrix::eye(n);
        for _ in 0..iters {
            let az = matmul(a, &z);
            // t1 = 7I − AZ
            let mut t1 = crate::tensor::sub(&ident, &az);
            scale_inplace(&mut t1, 1.0); // readability: t1 = I − AZ
            let mut seven = ident.clone();
            scale_inplace(&mut seven, 7.0);
            let t1 = crate::tensor::sub(&seven, &az);
            // t2 = 15I − AZ·t1
            let mut fifteen = ident.clone();
            scale_inplace(&mut fifteen, 15.0);
            let t2 = crate::tensor::sub(&fifteen, &matmul(&az, &t1));
            // t3 = 13I − AZ·t2
            let mut thirteen = ident.clone();
            scale_inplace(&mut thirteen, 13.0);
            let t3 = crate::tensor::sub(&thirteen, &matmul(&az, &t2));
            z = matmul(&z, &t3);
            scale_inplace(&mut z, 0.25);
        }
        z
    }
}

impl AttentionMethod for Nystromformer {
    fn name(&self) -> &'static str {
        "nystromformer"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        _rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, inputs.mask);
        let p = q.cols() as f32;
        let scale = 1.0 / p.sqrt();
        // one landmark count for both sides: the Nyström core A2 (and its
        // Newton–Schulz pseudo-inverse) must be square even when m != n
        let l = self.landmarks.min(q.rows()).min(k.rows());
        let (lq, lk) = (l, l);
        let mut q_land = scratch.matrix(lq, q.cols());
        Self::segment_means_into(q, l, &mut q_land);
        let mut k_land = scratch.matrix(lk, k.cols());
        Self::segment_means_into(k, l, &mut k_land);

        // F1 = softmax(Q K̃ᵀ)
        let mut f1 = scratch.matrix(q.rows(), lk);
        matmul_nt_into(q, &k_land, &mut f1);
        scale_inplace(&mut f1, scale);
        softmax_rows(&mut f1);
        // A2 = softmax(Q̃ K̃ᵀ)
        let mut a2 = scratch.matrix(lq, lk);
        matmul_nt_into(&q_land, &k_land, &mut a2);
        scale_inplace(&mut a2, scale);
        softmax_rows(&mut a2);
        scratch.recycle(k_land);
        // F3 = softmax(Q̃ Kᵀ) with padding mask on keys
        let mut f3 = scratch.matrix(lq, k.rows());
        matmul_nt_into(&q_land, k, &mut f3);
        scratch.recycle(q_land);
        scale_inplace(&mut f3, scale);
        masking::mask_score_columns(&mut f3, inputs.mask);
        softmax_rows(&mut f3);

        // the pseudo-inverse chain stays landmark-sized (L×L) — the
        // Newton–Schulz internals allocate, but only O(L²), never O(n²)
        let pinv = Self::newton_pinv(&a2, self.pinv_iters);
        scratch.recycle(a2);
        let mut f3v = scratch.matrix(f3.rows(), v.cols());
        matmul_into(&f3, v, &mut f3v);
        scratch.recycle(f3);
        let mut mid = scratch.matrix(pinv.rows(), v.cols());
        matmul_into(&pinv, &f3v, &mut mid);
        scratch.recycle(f3v);
        matmul_into(&f1, &mid, out);
        scratch.recycle(mid);
        scratch.recycle(f1);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // landmarks are segment means over the whole state; the session
        // recomputes them per query (epoch seed is unused — deterministic)
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::spectral_norm_diff;

    fn qkv(n: usize, p: usize, seed: u64, scale: f32) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = |s: f32| {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            scale_inplace(&mut m, s);
            m
        };
        (mk(scale), mk(scale), mk(1.0))
    }

    #[test]
    fn newton_pinv_inverts_well_conditioned() {
        // a diagonally-dominant row-stochastic-ish matrix
        let n = 8;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 0.8 } else { 0.2 / (n - 1) as f32 });
        let z = Nystromformer::newton_pinv(&a, 12);
        let prod = matmul(&a, &z);
        let eye = Matrix::eye(n);
        assert!(prod.max_abs_diff(&eye) < 1e-2, "AZ far from I");
    }

    #[test]
    fn segment_means_average_chunks() {
        let x = Matrix::from_fn(8, 2, |i, _| i as f32);
        let m = Nystromformer::segment_means(&x, 4);
        assert_eq!(m.rows(), 4);
        assert!((m.get(0, 0) - 0.5).abs() < 1e-6);
        assert!((m.get(3, 0) - 6.5).abs() < 1e-6);
    }

    #[test]
    fn more_landmarks_reduce_error() {
        let (q, k, v) = qkv(128, 8, 1, 1.0);
        let exact = Standard::exact(&q, &k, &v, None);
        let err = |m: usize| {
            spectral_norm_diff(
                &Nystromformer::new(m).compute(&q, &k, &v, None, &mut Rng::new(0)),
                &exact,
            )
        };
        assert!(err(64) < err(4), "landmarks 64 {} vs 4 {}", err(64), err(4));
    }

    #[test]
    fn near_exact_with_full_landmarks_on_smooth_inputs() {
        // Landmarks == n on smooth inputs: Nyström becomes near-exact.
        let n = 32;
        let (q, k, v) = qkv(n, 8, 3, 0.4);
        let exact = Standard::exact(&q, &k, &v, None);
        let out = Nystromformer::new(n).compute(&q, &k, &v, None, &mut Rng::new(0));
        let rel = spectral_norm_diff(&out, &exact) / crate::tensor::spectral_norm(&exact);
        assert!(rel < 0.25, "rel err {rel}");
    }
}
