//! Reformer (Kitaev, Kaiser & Levskaya 2020) — LSH attention, simplified to
//! a single hash round as in the paper's comparison (the paper notes
//! Reformer's FLOPs are input-dependent and excludes it from Table 5; we
//! keep the same chunked-sorted-buckets structure so the *runtime* shape is
//! faithful).
//!
//! Reformer ties Q = K; we follow that by hashing and scoring with Q only.

use super::{check_inputs, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Reformer {
    /// Number of hash buckets (must be even: ±projections).
    pub n_buckets: usize,
    /// Chunk size for sorted-bucket attention.
    pub chunk: usize,
}

impl Default for Reformer {
    fn default() -> Self {
        Self { n_buckets: 8, chunk: 16 }
    }
}

impl Reformer {
    /// Random-rotation LSH: bucket = argmax over [xR; −xR].
    fn buckets(&self, qk: &Matrix, rng: &mut Rng) -> Vec<usize> {
        let half = (self.n_buckets / 2).max(1);
        let p = qk.cols();
        let mut rot = Matrix::zeros(p, half);
        rng.fill_normal(rot.data_mut());
        (0..qk.rows())
            .map(|i| {
                let row = qk.row(i);
                let mut best = 0usize;
                let mut best_val = f32::NEG_INFINITY;
                for b in 0..half {
                    let mut acc = 0.0f32;
                    for (jj, &x) in row.iter().enumerate() {
                        acc += x * rot.get(jj, b);
                    }
                    if acc > best_val {
                        best_val = acc;
                        best = b;
                    }
                    if -acc > best_val {
                        best_val = -acc;
                        best = b + half;
                    }
                }
                best
            })
            .collect()
    }
}

impl AttentionMethod for Reformer {
    fn name(&self) -> &'static str {
        "reformer"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let n = q.rows();
        let p = q.cols() as f32;
        let scale = 1.0 / p.sqrt();
        let _ = k; // Q = K (Reformer shares the projection)

        let buckets = self.buckets(q, rng);
        // stable sort by bucket, preserving position order inside buckets
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (buckets[i], i));

        let chunk = self.chunk.min(n).max(1);
        let n_chunks = n.div_ceil(chunk);
        let mut out = Matrix::zeros(n, v.cols());

        for c in 0..n_chunks {
            let rows = c * chunk..((c + 1) * chunk).min(n);
            // keys: this chunk + previous chunk (wrapping), the standard scheme
            let prev = if c == 0 { n_chunks - 1 } else { c - 1 };
            let mut key_pos: Vec<usize> =
                (c * chunk..((c + 1) * chunk).min(n)).collect();
            if n_chunks > 1 {
                key_pos.extend(prev * chunk..((prev + 1) * chunk).min(n));
            }
            for ri in rows {
                let i = order[ri];
                let qi = q.row(i);
                let bi = buckets[i];
                let mut scores: Vec<f32> = Vec::with_capacity(key_pos.len());
                for &kp in &key_pos {
                    let j = order[kp];
                    let same_bucket = buckets[j] == bi;
                    let masked = mask.is_some_and(|m| m[j] <= 0.0);
                    if !same_bucket || masked {
                        scores.push(f32::NEG_INFINITY);
                    } else {
                        scores.push(crate::tensor::dot(qi, q.row(j)) * scale);
                    }
                }
                let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if !max.is_finite() {
                    // no same-bucket key visible (shouldn't happen: self is
                    // always visible unless masked) — leave the row zero.
                    continue;
                }
                let mut sum = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max).exp();
                    sum += *s;
                }
                let inv = 1.0 / sum;
                let orow = out.row_mut(i);
                for (&kp, &s) in key_pos.iter().zip(&scores) {
                    let w = s * inv;
                    if w > 0.0 {
                        crate::tensor::axpy(w, v.row(order[kp]), orow);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = || {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            m
        };
        (mk(), mk())
    }

    #[test]
    fn buckets_are_in_range_and_cluster_similar_vectors() {
        let n = 64;
        let p = 8;
        // two well-separated clusters
        let q = Matrix::from_fn(n, p, |i, j| {
            let center = if i < n / 2 { 5.0 } else { -5.0 };
            center + ((i * 7 + j) % 3) as f32 * 0.01
        });
        let ref_ = Reformer::default();
        let b = ref_.buckets(&q, &mut Rng::new(1));
        assert!(b.iter().all(|&x| x < ref_.n_buckets));
        // all of cluster 1 in one bucket, all of cluster 2 in another
        assert!(b[..n / 2].iter().all(|&x| x == b[0]));
        assert!(b[n / 2..].iter().all(|&x| x == b[n / 2]));
        assert_ne!(b[0], b[n / 2]);
    }

    #[test]
    fn output_shape_and_finiteness() {
        let (q, v) = qv(96, 8, 2);
        let out = Reformer::default().compute(&q, &q, &v, None, &mut Rng::new(3));
        assert_eq!(out.shape(), v.shape());
        assert!(out.all_finite());
    }

    #[test]
    fn rows_bounded_by_v_range() {
        let (q, v) = qv(64, 8, 4);
        let out = Reformer::default().compute(&q, &q, &v, None, &mut Rng::new(5));
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            // rows with no visible neighbor stay zero, which is within range
            // only if 0 ∈ [vmin, vmax]; allow that case explicitly.
            assert!(
                (x <= vmax + 1e-4 && x >= vmin - 1e-4) || x == 0.0,
                "out-of-range {x}"
            );
        }
    }

    #[test]
    fn attends_within_clusters() {
        // Two clusters with distinct V values: each token's output should be
        // near its own cluster's V mean, not the global mean.
        let n = 64;
        let p = 8;
        let q = Matrix::from_fn(n, p, |i, _| if i < n / 2 { 4.0 } else { -4.0 });
        let v = Matrix::from_fn(n, p, |i, _| if i < n / 2 { 1.0 } else { -1.0 });
        let out = Reformer { n_buckets: 4, chunk: 32 }.compute(&q, &q, &v, None, &mut Rng::new(7));
        for i in 0..n {
            let expect = if i < n / 2 { 1.0 } else { -1.0 };
            assert!(
                (out.get(i, 0) - expect).abs() < 0.2,
                "row {i}: {} vs {expect}",
                out.get(i, 0)
            );
        }
    }
}
