//! Reformer (Kitaev, Kaiser & Levskaya 2020) — LSH attention, simplified to
//! a single hash round as in the paper's comparison (the paper notes
//! Reformer's FLOPs are input-dependent and excludes it from Table 5; we
//! keep the same chunked-sorted-buckets structure so the *runtime* shape is
//! faithful).
//!
//! Reformer ties Q = K; we follow that by hashing and scoring with Q only.

use super::{
    check_inputs, AttentionMethod, AttentionSession, AttnInputs, AttnScratch, RecomputeSession,
    SessionSpec,
};
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Reformer {
    /// Number of hash buckets (must be even: ±projections).
    pub n_buckets: usize,
    /// Chunk size for sorted-bucket attention.
    pub chunk: usize,
}

impl Default for Reformer {
    fn default() -> Self {
        Self { n_buckets: 8, chunk: 16 }
    }
}

impl Reformer {
    /// Random-rotation LSH: bucket = argmax over [xR; −xR].
    #[cfg_attr(not(test), allow(dead_code))]
    fn buckets(&self, qk: &Matrix, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::new();
        self.buckets_into(qk, rng, &mut out, &mut AttnScratch::new());
        out
    }

    /// [`buckets`](Self::buckets) into a reused index buffer (cleared
    /// first), with the rotation drawn into scratch — the hot-loop form.
    fn buckets_into(
        &self,
        qk: &Matrix,
        rng: &mut Rng,
        out: &mut Vec<usize>,
        scratch: &mut AttnScratch,
    ) {
        let half = (self.n_buckets / 2).max(1);
        let p = qk.cols();
        let mut rot = scratch.matrix(p, half);
        rng.fill_normal(rot.data_mut());
        out.clear();
        out.extend((0..qk.rows()).map(|i| {
            let row = qk.row(i);
            let mut best = 0usize;
            let mut best_val = f32::NEG_INFINITY;
            for b in 0..half {
                let mut acc = 0.0f32;
                for (jj, &x) in row.iter().enumerate() {
                    acc += x * rot.get(jj, b);
                }
                if acc > best_val {
                    best_val = acc;
                    best = b;
                }
                if -acc > best_val {
                    best_val = -acc;
                    best = b + half;
                }
            }
            best
        }));
        scratch.recycle(rot);
    }
}

impl AttentionMethod for Reformer {
    fn name(&self) -> &'static str {
        "reformer"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        let (q, k, v) = (inputs.q, inputs.k, inputs.v);
        let mask = inputs.mask;
        check_inputs(self.name(), self.supports_cross_shape(), q, k, v, mask);
        let n = q.rows();
        let p = q.cols() as f32;
        let scale = 1.0 / p.sqrt();
        let _ = k; // Q = K (Reformer shares the projection)

        let mut buckets = scratch.idx_buf();
        self.buckets_into(q, rng, &mut buckets, scratch);
        // sort by bucket, preserving position order inside buckets — the
        // (bucket, position) key is a total order, so the allocation-free
        // unstable sort yields exactly the stable-sort permutation
        let mut order = scratch.idx_buf();
        order.extend(0..n);
        order.sort_unstable_by_key(|&i| (buckets[i], i));

        let chunk = self.chunk.min(n).max(1);
        let n_chunks = n.div_ceil(chunk);
        out.data_mut().iter_mut().for_each(|x| *x = 0.0);

        // per-chunk key list and per-row score strip, reused across the
        // whole pass instead of re-allocated per row (scratch audit)
        let mut key_pos = scratch.idx_buf();
        let mut scores = scratch.buf(0);

        for c in 0..n_chunks {
            let rows = c * chunk..((c + 1) * chunk).min(n);
            // keys: this chunk + previous chunk (wrapping), the standard scheme
            let prev = if c == 0 { n_chunks - 1 } else { c - 1 };
            key_pos.clear();
            key_pos.extend(c * chunk..((c + 1) * chunk).min(n));
            if n_chunks > 1 {
                key_pos.extend(prev * chunk..((prev + 1) * chunk).min(n));
            }
            for ri in rows {
                let i = order[ri];
                let qi = q.row(i);
                let bi = buckets[i];
                scores.clear();
                for &kp in key_pos.iter() {
                    let j = order[kp];
                    let same_bucket = buckets[j] == bi;
                    let masked = mask.is_some_and(|m| m[j] <= 0.0);
                    if !same_bucket || masked {
                        scores.push(f32::NEG_INFINITY);
                    } else {
                        scores.push(crate::tensor::dot(qi, q.row(j)) * scale);
                    }
                }
                let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                if !max.is_finite() {
                    // no same-bucket key visible (shouldn't happen: self is
                    // always visible unless masked) — leave the row zero.
                    continue;
                }
                let mut sum = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max).exp();
                    sum += *s;
                }
                let inv = 1.0 / sum;
                let orow = out.row_mut(i);
                for (&kp, &s) in key_pos.iter().zip(scores.iter()) {
                    let w = s * inv;
                    if w > 0.0 {
                        crate::tensor::axpy(w, v.row(order[kp]), orow);
                    }
                }
            }
        }
        scratch.recycle_buf(scores);
        scratch.recycle_idx(key_pos);
        scratch.recycle_idx(order);
        scratch.recycle_idx(buckets);
    }

    fn supports_cross_shape(&self) -> bool {
        // Reformer ties Q = K: a query row *is* a key row, so detached
        // m-row queries have no bucket assignment
        false
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        // square-only: session queries must supply all n query rows (Q=K
        // hashing needs every position); hashes re-draw on the epoch stride
        RecomputeSession::boxed(*self, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut mk = || {
            let mut m = Matrix::zeros(n, p);
            rng.fill_normal(m.data_mut());
            m
        };
        (mk(), mk())
    }

    #[test]
    fn buckets_are_in_range_and_cluster_similar_vectors() {
        let n = 64;
        let p = 8;
        // two well-separated clusters
        let q = Matrix::from_fn(n, p, |i, j| {
            let center = if i < n / 2 { 5.0 } else { -5.0 };
            center + ((i * 7 + j) % 3) as f32 * 0.01
        });
        let ref_ = Reformer::default();
        let b = ref_.buckets(&q, &mut Rng::new(1));
        assert!(b.iter().all(|&x| x < ref_.n_buckets));
        // all of cluster 1 in one bucket, all of cluster 2 in another
        assert!(b[..n / 2].iter().all(|&x| x == b[0]));
        assert!(b[n / 2..].iter().all(|&x| x == b[n / 2]));
        assert_ne!(b[0], b[n / 2]);
    }

    #[test]
    fn output_shape_and_finiteness() {
        let (q, v) = qv(96, 8, 2);
        let out = Reformer::default().compute(&q, &q, &v, None, &mut Rng::new(3));
        assert_eq!(out.shape(), v.shape());
        assert!(out.all_finite());
    }

    #[test]
    fn rows_bounded_by_v_range() {
        let (q, v) = qv(64, 8, 4);
        let out = Reformer::default().compute(&q, &q, &v, None, &mut Rng::new(5));
        let vmax = v.data().iter().copied().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().copied().fold(f32::MAX, f32::min);
        for &x in out.data() {
            // rows with no visible neighbor stay zero, which is within range
            // only if 0 ∈ [vmin, vmax]; allow that case explicitly.
            assert!(
                (x <= vmax + 1e-4 && x >= vmin - 1e-4) || x == 0.0,
                "out-of-range {x}"
            );
        }
    }

    #[test]
    fn attends_within_clusters() {
        // Two clusters with distinct V values: each token's output should be
        // near its own cluster's V mean, not the global mean.
        let n = 64;
        let p = 8;
        let q = Matrix::from_fn(n, p, |i, _| if i < n / 2 { 4.0 } else { -4.0 });
        let v = Matrix::from_fn(n, p, |i, _| if i < n / 2 { 1.0 } else { -1.0 });
        let out = Reformer { n_buckets: 4, chunk: 32 }.compute(&q, &q, &v, None, &mut Rng::new(7));
        for i in 0..n {
            let expect = if i < n / 2 { 1.0 } else { -1.0 };
            assert!(
                (out.get(i, 0) - expect).abs() < 0.2,
                "row {i}: {} vs {expect}",
                out.get(i, 0)
            );
        }
    }
}
