//! The rank-one "V-Mean" baseline: `(1/n) 1 1ᵀ V`.
//!
//! The paper uses this as the ablation for pure row normalization — it is
//! adaptive row normalization with *zero* sub-samples, and its surprising
//! strength on some LRA tasks (Table 1: best Text score) is one of the
//! paper's observations.

use super::{
    check_inputs, masking, AttentionMethod, AttentionSession, AttnInputs, AttnScratch,
    SessionSpec, VMeanSession,
};
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, Default)]
pub struct VMean;

impl AttentionMethod for VMean {
    fn name(&self) -> &'static str {
        "vmean"
    }

    fn compute_rng_into(
        &self,
        inputs: &AttnInputs<'_>,
        _rng: &mut Rng,
        out: &mut Matrix,
        scratch: &mut AttnScratch,
    ) {
        check_inputs(self.name(), self.supports_cross_shape(), inputs.q, inputs.k, inputs.v, inputs.mask);
        let v = inputs.v;
        let m = masking::valid_count(inputs.mask, v.rows());
        let mut sums = scratch.buf(v.cols());
        masking::masked_col_sums_into(v, inputs.mask, &mut sums);
        for i in 0..out.rows() {
            for (o, &s) in out.row_mut(i).iter_mut().zip(&sums) {
                *o = s / m;
            }
        }
        scratch.recycle_buf(sums);
    }

    fn supports_cross_shape(&self) -> bool {
        true
    }

    fn session_is_exact_incremental(&self) -> bool {
        true // running column sums: O(p) state, no stored K/V
    }

    fn begin_session(&self, spec: SessionSpec) -> Box<dyn AttentionSession> {
        Box::new(VMeanSession::new(spec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_is_mean_of_v() {
        let v = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 6.0]]);
        let out = VMean.compute(&v, &v, &v, None, &mut Rng::new(0));
        for i in 0..2 {
            assert_eq!(out.row(i), &[2.0, 4.0]);
        }
    }

    #[test]
    fn ignores_q_and_k_entirely() {
        let v = Matrix::from_fn(8, 4, |i, j| (i * 4 + j) as f32);
        let q1 = Matrix::zeros(8, 4);
        let q2 = Matrix::full(8, 4, 123.0);
        let a = VMean.compute(&q1, &q1, &v, None, &mut Rng::new(0));
        let b = VMean.compute(&q2, &q2, &v, None, &mut Rng::new(1));
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn masked_rows_excluded_from_mean() {
        let v = Matrix::from_rows(&[vec![2.0], vec![4.0], vec![1000.0]]);
        let mask = [1.0, 1.0, 0.0];
        let out = VMean.compute(&v, &v, &v, Some(&mask), &mut Rng::new(0));
        assert_eq!(out.get(0, 0), 3.0);
    }
}
