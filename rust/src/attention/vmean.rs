//! The rank-one "V-Mean" baseline: `(1/n) 1 1ᵀ V`.
//!
//! The paper uses this as the ablation for pure row normalization — it is
//! adaptive row normalization with *zero* sub-samples, and its surprising
//! strength on some LRA tasks (Table 1: best Text score) is one of the
//! paper's observations.

use super::{check_inputs, masking, AttentionMethod};
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, Default)]
pub struct VMean;

impl AttentionMethod for VMean {
    fn name(&self) -> &'static str {
        "vmean"
    }

    fn compute(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        mask: Option<&[f32]>,
        _rng: &mut Rng,
    ) -> Matrix {
        check_inputs(q, k, v, mask);
        let n = v.rows();
        let m = masking::valid_count(mask, n);
        let sums = masking::masked_col_sums(v, mask);
        let mean: Vec<f32> = sums.iter().map(|s| s / m).collect();
        Matrix::from_fn(n, v.cols(), |_, j| mean[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_is_mean_of_v() {
        let v = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 6.0]]);
        let out = VMean.compute(&v, &v, &v, None, &mut Rng::new(0));
        for i in 0..2 {
            assert_eq!(out.row(i), &[2.0, 4.0]);
        }
    }

    #[test]
    fn ignores_q_and_k_entirely() {
        let v = Matrix::from_fn(8, 4, |i, j| (i * 4 + j) as f32);
        let q1 = Matrix::zeros(8, 4);
        let q2 = Matrix::full(8, 4, 123.0);
        let a = VMean.compute(&q1, &q1, &v, None, &mut Rng::new(0));
        let b = VMean.compute(&q2, &q2, &v, None, &mut Rng::new(1));
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn masked_rows_excluded_from_mean() {
        let v = Matrix::from_rows(&[vec![2.0], vec![4.0], vec![1000.0]]);
        let mask = [1.0, 1.0, 0.0];
        let out = VMean.compute(&v, &v, &v, Some(&mask), &mut Rng::new(0));
        assert_eq!(out.get(0, 0), 3.0);
    }
}
