//! Q, K, V generators for the Figure-1 approximation study.
//!
//! The paper feeds the study with Wikitext-2 text embedded by a pretrained
//! bert-base-cased model, projected by either pretrained or randomly
//! initialised W_Q/K/V.  Offline we cannot load BERT, so we synthesise
//! inputs with the *statistics that matter for the experiment* (see
//! DESIGN.md §10): pretrained embeddings are strongly anisotropic (a few
//! dominant directions + token clusters), which is what produces peaked,
//! low-rank attention; random init is isotropic and produces near-uniform
//! attention.  Both modes are provided, exactly as the paper sweeps both.

use crate::rng::Rng;
use crate::tensor::{matmul, Matrix};

/// Which embedding statistics to mimic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QkvMode {
    /// Anisotropic, clustered token embeddings → peaked attention
    /// (the "pretrained" curve in Figure 1).
    Pretrained,
    /// Isotropic Gaussian embeddings → flat attention
    /// (the "randomly initiated" curve).
    RandomInit,
}

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct QkvConfig {
    pub n: usize,
    pub p: usize,
    pub mode: QkvMode,
    /// Number of token clusters (vocabulary-like repetition in text).
    pub clusters: usize,
    /// Number of dominant embedding directions.
    pub dominant_dirs: usize,
}

impl QkvConfig {
    pub fn pretrained(n: usize, p: usize) -> Self {
        Self { n, p, mode: QkvMode::Pretrained, clusters: 24, dominant_dirs: 4 }
    }

    pub fn random_init(n: usize, p: usize) -> Self {
        Self { n, p, mode: QkvMode::RandomInit, clusters: 0, dominant_dirs: 0 }
    }
}

/// One (Q, K, V) triple.
pub fn generate(cfg: &QkvConfig, rng: &mut Rng) -> (Matrix, Matrix, Matrix) {
    match cfg.mode {
        QkvMode::RandomInit => {
            let mk = |r: &mut Rng| {
                let mut m = Matrix::zeros(cfg.n, cfg.p);
                r.fill_normal(m.data_mut());
                m
            };
            (mk(rng), mk(rng), mk(rng))
        }
        QkvMode::Pretrained => {
            // token-level structure: each position belongs to a cluster
            // (Zipf-ish usage), embeddings = cluster centroid + small noise,
            // with extra mass along a few dominant directions.
            let e = cfg.p * 2; // "input embedding" dim before projection
            let mut centroids = Matrix::zeros(cfg.clusters.max(1), e);
            rng.fill_normal(centroids.data_mut());
            crate::tensor::scale_inplace(&mut centroids, 2.0);

            let mut dirs = Matrix::zeros(cfg.dominant_dirs.max(1), e);
            rng.fill_normal(dirs.data_mut());

            let mut x = Matrix::zeros(cfg.n, e);
            for i in 0..cfg.n {
                // Zipf-like cluster pick: cluster c w.p. ∝ 1/(c+1)
                let weights: Vec<f32> =
                    (0..cfg.clusters.max(1)).map(|c| 1.0 / (c + 1) as f32).collect();
                let c = rng.categorical(&weights);
                let noise_scale = 0.35;
                for (j, xv) in x.row_mut(i).iter_mut().enumerate() {
                    *xv = centroids.get(c, j) + rng.normal() * noise_scale;
                }
                // anisotropy: add shared dominant-direction components
                for dd in 0..cfg.dominant_dirs.max(1) {
                    let coeff = rng.normal() * 1.5;
                    for (j, xv) in x.row_mut(i).iter_mut().enumerate() {
                        *xv += coeff * dirs.get(dd, j) / (e as f32).sqrt();
                    }
                }
            }
            // random projection heads W_Q/K/V : (e, p) — "pretrained" heads
            // differ from random init mainly through X, which carries the
            // structure; the heads stay Gaussian as in a fresh task head.
            let mk_head = |r: &mut Rng| {
                let mut w = Matrix::zeros(e, cfg.p);
                r.fill_normal(w.data_mut());
                crate::tensor::scale_inplace(&mut w, 1.0 / (e as f32).sqrt());
                w
            };
            let wq = mk_head(rng);
            let wk = mk_head(rng);
            let wv = mk_head(rng);
            (matmul(&x, &wq), matmul(&x, &wk), matmul(&x, &wv))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Standard;
    use crate::tensor::softmax_rows;

    fn attention_entropy(q: &Matrix, k: &Matrix) -> f32 {
        let p = q.cols() as f32;
        let mut s = crate::tensor::matmul_nt(q, k);
        crate::tensor::scale_inplace(&mut s, 1.0 / p.sqrt());
        softmax_rows(&mut s);
        let n = s.rows();
        let mut h = 0.0f32;
        for i in 0..n {
            for &x in s.row(i) {
                if x > 0.0 {
                    h -= x * x.ln();
                }
            }
        }
        h / n as f32
    }

    #[test]
    fn shapes_are_correct() {
        let mut rng = Rng::new(1);
        let (q, k, v) = generate(&QkvConfig::pretrained(64, 16), &mut rng);
        assert_eq!(q.shape(), (64, 16));
        assert_eq!(k.shape(), (64, 16));
        assert_eq!(v.shape(), (64, 16));
        assert!(q.all_finite() && k.all_finite() && v.all_finite());
    }

    #[test]
    fn pretrained_mode_is_peakier_than_random() {
        // lower attention-row entropy == peakier rows
        let mut rng = Rng::new(2);
        let (qp, kp, _) = generate(&QkvConfig::pretrained(128, 16), &mut rng);
        let (qr, kr, _) = generate(&QkvConfig::random_init(128, 16), &mut rng);
        let hp = attention_entropy(&qp, &kp);
        let hr = attention_entropy(&qr, &kr);
        assert!(hp < hr, "pretrained entropy {hp} !< random {hr}");
    }

    #[test]
    fn pretrained_attention_is_approximately_low_rank() {
        // the rank-collapse phenomenon the paper cites: exact output is
        // well-approximated by a modest-rank object; proxy test — V-Mean
        // error is notably below worst case.
        let mut rng = Rng::new(3);
        let (q, k, v) = generate(&QkvConfig::pretrained(96, 16), &mut rng);
        let exact = Standard::exact(&q, &k, &v, None);
        assert!(exact.all_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = QkvConfig::pretrained(32, 8);
        let (q1, ..) = generate(&cfg, &mut Rng::new(7));
        let (q2, ..) = generate(&cfg, &mut Rng::new(7));
        assert_eq!(q1.max_abs_diff(&q2), 0.0);
    }
}
