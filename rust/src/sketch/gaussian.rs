//! Gaussian (sub-Gaussian / JL) sketching matrices (Definition 3.2).
//!
//! Entries are i.i.d. `N(0, 1/d)`, so `E[S Sᵀ] = I` and with
//! `d = O(ε⁻² log(1/δ))` the sketch satisfies the oblivious
//! (ε, δ)-JL guarantee — verified empirically by the tests below.

use super::Sketch;
use crate::rng::Rng;
use crate::tensor::{axpy, dot, Matrix};

#[derive(Clone, Copy, Debug)]
pub struct GaussianSketch {
    n: usize,
    d: usize,
}

impl GaussianSketch {
    pub fn new(n: usize, d: usize) -> Self {
        Self { n, d }
    }
}

impl Sketch for GaussianSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn draw(&self, rng: &mut Rng) -> Matrix {
        let std = 1.0 / (self.d as f32).sqrt();
        let mut s = Matrix::zeros(self.n, self.d);
        for x in s.data_mut() {
            *x = rng.normal() * std;
        }
        s
    }
}

/// Empirical JL check: fraction of draws where
/// `| ‖Sᵀb‖² − ‖b‖² | > ε ‖b‖²` (Eq. 2, with S applied on the left as in
/// Definition 3.2's convention `‖S b‖` for S: R^n → R^d — our S is n×d so
/// the mapped vector is `Sᵀ b`).
pub fn jl_failure_rate(
    sketch: &GaussianSketch,
    b: &[f32],
    eps: f32,
    trials: usize,
    seed: u64,
) -> f32 {
    assert_eq!(b.len(), sketch.n());
    let bn2 = dot(b, b);
    let mut rng = Rng::new(seed);
    let mut fails = 0usize;
    for _ in 0..trials {
        let s = sketch.draw(&mut rng);
        // Sᵀ b — rank-1 accumulation on the shared saxpy kernel, with
        // matmul_tn's zero-coefficient skip
        let mut proj = vec![0.0f32; sketch.d()];
        for i in 0..sketch.n() {
            let bi = b[i];
            if bi != 0.0 {
                axpy(bi, s.row(i), &mut proj);
            }
        }
        let pn2 = dot(&proj, &proj);
        if (pn2 - bn2).abs() > eps * bn2 {
            fails += 1;
        }
    }
    fails as f32 / trials as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jl_guarantee_holds_at_sufficient_d() {
        // d = 128, ε = 0.5 ⇒ failure rate should be far below 10%.
        let sk = GaussianSketch::new(64, 128);
        let b: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.3).sin() + 0.1).collect();
        let rate = jl_failure_rate(&sk, &b, 0.5, 300, 7);
        assert!(rate < 0.05, "failure rate {rate}");
    }

    #[test]
    fn jl_degrades_at_tiny_d() {
        let b: Vec<f32> = (0..64).map(|i| (i as f32 * 0.2).cos()).collect();
        let tight = jl_failure_rate(&GaussianSketch::new(64, 2), &b, 0.2, 300, 8);
        let loose = jl_failure_rate(&GaussianSketch::new(64, 256), &b, 0.2, 300, 9);
        assert!(tight > loose, "d=2 rate {tight} vs d=256 rate {loose}");
    }

    #[test]
    fn entries_have_variance_one_over_d() {
        let sk = GaussianSketch::new(32, 50);
        let mut rng = Rng::new(10);
        let s = sk.draw(&mut rng);
        let var: f32 =
            s.data().iter().map(|x| x * x).sum::<f32>() / (32.0 * 50.0);
        assert!((var - 1.0 / 50.0).abs() < 0.005, "var {var}");
    }
}
