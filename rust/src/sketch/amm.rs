//! Approximate matrix multiplication (Proposition 1 / Drineas-Kannan-
//! Mahoney Theorem 1): `B V ≈ B S Sᵀ V` with sub-sampling probabilities
//! `p_i ∝ ‖B^(i)‖ ‖V_(i)‖`, and the Frobenius error bound
//!
//! `‖BV − BSSᵀV‖_F² ≤ (η²/βd) ‖B‖_F² ‖V‖_F²`  w.p. ≥ 1 − δ.
//!
//! The property suite samples many draws and asserts the bound's empirical
//! quantiles — the executable version of the paper's Proposition 1.

use super::subsample::SubSampleSketch;
use crate::rng::Rng;
use crate::tensor::{col_norms, frobenius_norm, matmul, row_norms, Matrix};

/// The optimal DKM probabilities `p_i ∝ ‖B^(i)‖ ‖V_(i)‖`.
pub fn optimal_probabilities(b: &Matrix, v: &Matrix) -> Vec<f32> {
    let bc = col_norms(b);
    let vr = row_norms(v);
    bc.iter().zip(&vr).map(|(x, y)| x * y).collect()
}

/// One draw of the AMM estimator `B S Sᵀ V` using the index fast path
/// (never materialises S): gather + rescale columns of B and rows of V.
pub fn amm_approximate(
    b: &Matrix,
    v: &Matrix,
    sketch: &SubSampleSketch,
    rng: &mut Rng,
) -> Matrix {
    let (idx, scales) = sketch.draw_indices(rng);
    // BS: (n_B, d) — scaled column gather of B
    let bs = Matrix::from_fn(b.rows(), idx.len(), |r, c| b.get(r, idx[c]) * scales[c]);
    // SᵀV: (d, p) — scaled row gather of V
    let sv = Matrix::from_fn(idx.len(), v.cols(), |r, c| v.get(idx[r], c) * scales[r]);
    matmul(&bs, &sv)
}

/// The right-hand side of Eq. (4): `(η²/βd)‖B‖_F²‖V‖_F²` with
/// `η = 1 + sqrt((8/β) log(1/δ))`.
pub fn amm_error_bound(b: &Matrix, v: &Matrix, d: usize, beta: f32, delta: f32) -> f32 {
    let eta = 1.0 + ((8.0 / beta) * (1.0 / delta).ln()).sqrt();
    (eta * eta) / (beta * d as f32) * frobenius_norm(b).powi(2) * frobenius_norm(v).powi(2)
}

/// Summary statistics over repeated AMM draws.
#[derive(Clone, Copy, Debug)]
pub struct AmmStats {
    pub mean_sq_err: f32,
    pub max_sq_err: f32,
    pub bound: f32,
}

/// Run `trials` draws and compare squared Frobenius errors to the bound.
pub fn amm_trials(
    b: &Matrix,
    v: &Matrix,
    d: usize,
    beta: f32,
    delta: f32,
    trials: usize,
    seed: u64,
) -> AmmStats {
    let probs = optimal_probabilities(b, v);
    let sketch = SubSampleSketch::new(probs, d);
    let exact = matmul(b, v);
    let mut rng = Rng::new(seed);
    let mut sum = 0.0f64;
    let mut max = 0.0f32;
    for _ in 0..trials {
        let approx = amm_approximate(b, v, &sketch, &mut rng);
        let err = frobenius_norm(&crate::tensor::sub(&approx, &exact)).powi(2);
        sum += err as f64;
        max = max.max(err);
    }
    AmmStats {
        mean_sq_err: (sum / trials as f64) as f32,
        max_sq_err: max,
        bound: amm_error_bound(b, v, d, beta, delta),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mats(n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let mut b = Matrix::zeros(n, n);
        rng.fill_normal(b.data_mut());
        // make B row-stochastic-ish (like an attention matrix)
        crate::tensor::softmax_rows(&mut b);
        let mut v = Matrix::zeros(n, p);
        rng.fill_normal(v.data_mut());
        (b, v)
    }

    #[test]
    fn estimator_is_unbiased() {
        // average of many draws converges to BV
        let (b, v) = mats(24, 4, 1);
        let probs = optimal_probabilities(&b, &v);
        let sk = SubSampleSketch::new(probs, 8);
        let exact = matmul(&b, &v);
        let mut acc = Matrix::zeros(24, 4);
        let trials = 3000;
        let mut rng = Rng::new(2);
        for _ in 0..trials {
            let a = amm_approximate(&b, &v, &sk, &mut rng);
            for (x, &y) in acc.data_mut().iter_mut().zip(a.data()) {
                *x += y;
            }
        }
        acc.data_mut().iter_mut().for_each(|x| *x /= trials as f32);
        let rel = frobenius_norm(&crate::tensor::sub(&acc, &exact)) / frobenius_norm(&exact);
        assert!(rel < 0.1, "bias {rel}");
    }

    #[test]
    fn proposition_1_bound_holds_empirically() {
        let (b, v) = mats(32, 8, 3);
        let stats = amm_trials(&b, &v, 16, 1.0, 0.1, 200, 4);
        // the bound is a ≥(1−δ) high-probability bound; the max over 200
        // draws exceeding it would be a clear violation
        assert!(
            stats.max_sq_err <= stats.bound,
            "max {} > bound {}",
            stats.max_sq_err,
            stats.bound
        );
        assert!(stats.mean_sq_err < stats.bound / 4.0);
    }

    #[test]
    fn error_decreases_with_d() {
        let (b, v) = mats(32, 8, 5);
        let e8 = amm_trials(&b, &v, 8, 1.0, 0.1, 100, 6).mean_sq_err;
        let e64 = amm_trials(&b, &v, 64, 1.0, 0.1, 100, 7).mean_sq_err;
        assert!(e64 < e8, "d=8 {e8} vs d=64 {e64}");
    }

    #[test]
    fn optimal_probs_match_formula() {
        let (b, v) = mats(8, 3, 9);
        let probs = optimal_probabilities(&b, &v);
        for (i, p) in probs.iter().enumerate() {
            let bc: f32 = (0..8).map(|r| b.get(r, i).powi(2)).sum::<f32>().sqrt();
            let vr: f32 = v.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((p - bc * vr).abs() < 1e-5);
        }
    }
}
