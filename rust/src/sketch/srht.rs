//! Subsampled Randomized Hadamard Transform (SRHT) sketching — the third
//! construction §3.2 lists (Ailon-Chazelle 2006; Lu et al. 2013).
//!
//! `S = sqrt(n/d) · D H P`, where `D` is a random ±1 diagonal, `H` the
//! (normalised) Walsh-Hadamard transform and `P` a uniform column
//! sub-sampler.  Applying `Sᵀ` to a vector costs O(n log n) via the fast
//! WHT instead of O(n d) for a dense Gaussian sketch — the sketching
//! counterpart of the paper's complexity target.

use super::Sketch;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// In-place fast Walsh-Hadamard transform (unnormalised); `x.len()` must
/// be a power of two.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT needs a power-of-two length");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SrhtSketch {
    n: usize,
    d: usize,
}

impl SrhtSketch {
    /// `n` must be a power of two (pad externally otherwise).
    pub fn new(n: usize, d: usize) -> Self {
        assert!(n.is_power_of_two(), "SRHT needs power-of-two n");
        Self { n, d }
    }

    /// Draw the structured representation: (sign diagonal, sampled columns).
    pub fn draw_parts(&self, rng: &mut Rng) -> (Vec<f32>, Vec<usize>) {
        let signs: Vec<f32> =
            (0..self.n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let cols: Vec<usize> = (0..self.d).map(|_| rng.below(self.n)).collect();
        (signs, cols)
    }

    /// Fast path: `Bᵀ ← Sᵀ x` for one vector in O(n log n).
    pub fn apply_t(&self, x: &[f32], signs: &[f32], cols: &[usize]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut buf: Vec<f32> = x.iter().zip(signs).map(|(a, s)| a * s).collect();
        fwht(&mut buf);
        // normalised H: divide by sqrt(n); overall scale sqrt(n/d)/sqrt(n)
        let scale = 1.0 / (self.d as f32).sqrt();
        cols.iter().map(|&c| buf[c] * scale).collect()
    }
}

impl Sketch for SrhtSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn draw(&self, rng: &mut Rng) -> Matrix {
        let (signs, cols) = self.draw_parts(rng);
        // column k of S is sqrt(n/d)·D H e_{c_k} / sqrt(n) = D·H[:,c_k]/sqrt(d)
        let mut s = Matrix::zeros(self.n, self.d);
        for (k, &c) in cols.iter().enumerate() {
            // H[:,c] entries are ±1 (Hadamard); H[i,c] = (-1)^{popcount(i&c)}
            for i in 0..self.n {
                let h = if (i & c).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                s.set(i, k, signs[i] * h / (self.d as f32).sqrt());
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_hadamard_matrix() {
        // H e_j gives column j: entries (-1)^{popcount(i&j)}
        let n = 8;
        for j in 0..n {
            let mut x = vec![0.0f32; n];
            x[j] = 1.0;
            fwht(&mut x);
            for (i, &v) in x.iter().enumerate() {
                let expect = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                assert_eq!(v, expect, "H[{i},{j}]");
            }
        }
    }

    #[test]
    fn fwht_is_self_inverse_up_to_n() {
        let n = 16;
        let orig: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut x = orig.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / n as f32 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fast_apply_matches_dense_draw() {
        let sk = SrhtSketch::new(16, 6);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut rng1 = Rng::new(5);
        let (signs, cols) = sk.draw_parts(&mut rng1);
        let fast = sk.apply_t(&x, &signs, &cols);
        // dense: same RNG stream -> same parts
        let mut rng2 = Rng::new(5);
        let s = sk.draw(&mut rng2);
        // Sᵀ x
        let mut dense = vec![0.0f32; 6];
        for k in 0..6 {
            for i in 0..16 {
                dense[k] += s.get(i, k) * x[i];
            }
        }
        for (f, d) in fast.iter().zip(&dense) {
            assert!((f - d).abs() < 1e-4, "fast {f} vs dense {d}");
        }
    }

    #[test]
    fn srht_expectation_is_identity() {
        let sk = SrhtSketch::new(16, 8);
        let dev = crate::sketch::expectation_deviation(&sk, 3000, 11);
        assert!(dev < 0.25, "E[SSᵀ] deviation {dev}");
    }

    #[test]
    fn srht_preserves_norms_on_average() {
        let sk = SrhtSketch::new(64, 32);
        let x: Vec<f32> = (0..64).map(|i| ((i * 7 % 13) as f32) * 0.1 - 0.5).collect();
        let xn2: f32 = x.iter().map(|a| a * a).sum();
        let mut rng = Rng::new(7);
        let trials = 200;
        let mut est = 0.0f64;
        for _ in 0..trials {
            let (signs, cols) = sk.draw_parts(&mut rng);
            let proj = sk.apply_t(&x, &signs, &cols);
            est += proj.iter().map(|a| (a * a) as f64).sum::<f64>();
        }
        est /= trials as f64;
        assert!((est / xn2 as f64 - 1.0).abs() < 0.15, "ratio {}", est / xn2 as f64);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        let _ = SrhtSketch::new(12, 4);
    }
}
