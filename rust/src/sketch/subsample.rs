//! Sub-sampling sketching matrices (Definition 3.1).
//!
//! Column j of S is `e_i / sqrt(d p_i)` with probability `p_i` — i.i.d.
//! across columns (sampling *with* replacement, exactly as in
//! Drineas-Kannan-Mahoney).  `E[S Sᵀ] = Σ_i p_i e_i e_iᵀ/(d p_i) · d = I`.

use super::Sketch;
use crate::rng::{alias_table, AliasTable, Rng};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct SubSampleSketch {
    probs: Vec<f32>,
    d: usize,
    table: AliasTable,
}

impl SubSampleSketch {
    /// `probs` must be a probability vector (positive entries may be
    /// unnormalised; they are normalised internally).
    pub fn new(mut probs: Vec<f32>, d: usize) -> Self {
        let total: f32 = probs.iter().map(|p| p.max(0.0)).sum();
        assert!(total > 0.0, "need positive probability mass");
        probs.iter_mut().for_each(|p| *p = p.max(0.0) / total);
        let table = alias_table(&probs);
        Self { probs, d, table }
    }

    pub fn probs(&self) -> &[f32] {
        &self.probs
    }

    /// Draw the index/scale representation: `(indices, scales)` where
    /// column k of S is `scales[k] * e_{indices[k]}`.  Allocating wrapper
    /// over [`draw_indices_into`](Self::draw_indices_into).
    pub fn draw_indices(&self, rng: &mut Rng) -> (Vec<usize>, Vec<f32>) {
        let mut idx = Vec::new();
        let mut scales = Vec::new();
        self.draw_indices_into(rng, &mut idx, &mut scales);
        (idx, scales)
    }

    /// [`draw_indices`](Self::draw_indices) into caller-provided buffers
    /// (cleared first) — hot loops recycle `idx`/`scales` (e.g. through
    /// `attention::AttnScratch`) and pay no per-draw allocation.  Same
    /// RNG stream, same draws as the allocating version.
    pub fn draw_indices_into(&self, rng: &mut Rng, idx: &mut Vec<usize>, scales: &mut Vec<f32>) {
        idx.clear();
        idx.extend((0..self.d).map(|_| self.table.draw(rng)));
        scales.clear();
        scales.extend(idx.iter().map(|&i| 1.0 / (self.d as f32 * self.probs[i]).sqrt()));
    }
}

impl Sketch for SubSampleSketch {
    fn n(&self) -> usize {
        self.probs.len()
    }

    fn d(&self) -> usize {
        self.d
    }

    fn draw(&self, rng: &mut Rng) -> Matrix {
        let mut idx = Vec::with_capacity(self.d);
        let mut scales = Vec::with_capacity(self.d);
        self.draw_indices_into(rng, &mut idx, &mut scales);
        let mut s = Matrix::zeros(self.n(), self.d);
        for (col, (&i, &sc)) in idx.iter().zip(&scales).enumerate() {
            s.set(i, col, sc);
        }
        s
    }

    /// Fast path: `B S` is a scaled column gather — O(n_B · d) instead of
    /// O(n_B · n · d).  Callers that draw repeatedly can hold `idx`/`scales`
    /// buffers and use [`SubSampleSketch::draw_indices_into`] +
    /// [`Matrix::from_fn`] themselves to skip the per-draw Vecs.
    fn sketch_right(&self, b: &Matrix, rng: &mut Rng) -> Matrix {
        let mut idx = Vec::with_capacity(self.d);
        let mut scales = Vec::with_capacity(self.d);
        self.draw_indices_into(rng, &mut idx, &mut scales);
        Matrix::from_fn(b.rows(), self.d, |r, c| b.get(r, idx[c]) * scales[c])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    #[test]
    fn columns_are_scaled_basis_vectors() {
        let sk = SubSampleSketch::new(vec![0.25; 4], 6);
        let mut rng = Rng::new(1);
        let s = sk.draw(&mut rng);
        for c in 0..6 {
            // borrowed column iterator: no per-column allocation
            let nonzero: Vec<(usize, f32)> = s
                .col_iter(c)
                .enumerate()
                .filter(|(_, x)| *x != 0.0)
                .collect();
            assert_eq!(nonzero.len(), 1, "column {c} not a basis vector");
            let expect = 1.0 / (6.0f32 * 0.25).sqrt();
            assert!((nonzero[0].1 - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn fast_sketch_right_matches_dense() {
        let b = Matrix::from_fn(5, 12, |i, j| (i * 12 + j) as f32 * 0.1);
        let probs: Vec<f32> = (1..=12).map(|i| i as f32).collect();
        let sk = SubSampleSketch::new(probs, 4);
        let dense = {
            let mut rng = Rng::new(9);
            let s = sk.draw(&mut rng);
            matmul(&b, &s)
        };
        let fast = {
            let mut rng = Rng::new(9);
            sk.sketch_right(&b, &mut rng)
        };
        assert!(dense.max_abs_diff(&fast) < 1e-5);
    }

    #[test]
    fn zero_probability_rows_never_sampled() {
        let mut probs = vec![1.0f32; 10];
        probs[3] = 0.0;
        probs[7] = 0.0;
        let sk = SubSampleSketch::new(probs, 16);
        let mut rng = Rng::new(5);
        // reused draw buffers: the repeated-draw loop pays no per-draw
        // allocation (the pattern hot call sites follow)
        let mut idx = Vec::new();
        let mut scales = Vec::new();
        for _ in 0..100 {
            sk.draw_indices_into(&mut rng, &mut idx, &mut scales);
            assert!(idx.iter().all(|&i| i != 3 && i != 7));
        }
    }

    #[test]
    #[should_panic]
    fn all_zero_mass_panics() {
        let _ = SubSampleSketch::new(vec![0.0; 4], 2);
    }

    #[test]
    fn draw_indices_into_matches_allocating_exactly() {
        let sk = SubSampleSketch::new((1..=9).map(|i| i as f32).collect(), 5);
        let (want_idx, want_scales) = sk.draw_indices(&mut Rng::new(13));
        let mut idx = vec![7usize; 2]; // dirty reused buffers
        let mut scales = vec![0.5f32; 9];
        sk.draw_indices_into(&mut Rng::new(13), &mut idx, &mut scales);
        assert_eq!(idx, want_idx);
        assert_eq!(scales, want_scales);
    }
}
