//! The sketching framework of §3: random sketching matrices `S` with
//! `E[S Sᵀ] = I` and the approximate-matrix-multiplication (AMM) machinery
//! of Proposition 1.
//!
//! This module is the paper's *theory* made executable: the property tests
//! verify Definition 3.1's expectation identity, the JL guarantee of
//! Definition 3.2, and the Frobenius error bound of Proposition 1 —
//! empirically, over many random draws.

mod amm;
mod gaussian;
mod sparse;
mod srht;
mod subsample;

pub use amm::{amm_approximate, amm_error_bound, amm_trials, optimal_probabilities, AmmStats};
pub use gaussian::{jl_failure_rate, GaussianSketch};
pub use sparse::VerySparseSketch;
pub use srht::{fwht, SrhtSketch};
pub use subsample::SubSampleSketch;

use crate::rng::Rng;
use crate::tensor::Matrix;

/// A random sketching matrix S ∈ R^{n×d} satisfying `E[S Sᵀ] = I` (Eq. 1).
pub trait Sketch {
    /// Source dimension n.
    fn n(&self) -> usize;
    /// Sketch dimension d.
    fn d(&self) -> usize;
    /// Materialise a fresh random draw of S.
    fn draw(&self, rng: &mut Rng) -> Matrix;

    /// `B S` without materialising S when a structured fast-path exists.
    fn sketch_right(&self, b: &Matrix, rng: &mut Rng) -> Matrix {
        crate::tensor::matmul(b, &self.draw(rng))
    }
}

/// Empirical check of Eq. (1): average `S Sᵀ` over `trials` draws and
/// return the max deviation from the identity. Used by property tests.
pub fn expectation_deviation(sketch: &dyn Sketch, trials: usize, seed: u64) -> f32 {
    let n = sketch.n();
    let mut acc = Matrix::zeros(n, n);
    let mut rng = Rng::new(seed);
    for _ in 0..trials {
        let s = sketch.draw(&mut rng);
        let sst = crate::tensor::matmul_nt(&s, &s);
        for (a, &b) in acc.data_mut().iter_mut().zip(sst.data()) {
            *a += b;
        }
    }
    let inv = 1.0 / trials as f32;
    let eye = Matrix::eye(n);
    let mut max_dev = 0.0f32;
    for (i, (&a, &e)) in acc.data().iter().zip(eye.data()).enumerate() {
        let _ = i;
        max_dev = max_dev.max((a * inv - e).abs());
    }
    max_dev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsample_expectation_is_identity() {
        // uniform probabilities
        let n = 24;
        let probs = vec![1.0 / n as f32; n];
        let sk = SubSampleSketch::new(probs, 8);
        let dev = expectation_deviation(&sk, 4000, 1);
        assert!(dev < 0.25, "deviation {dev}");
    }

    #[test]
    fn subsample_expectation_nonuniform() {
        let n = 16;
        let mut probs: Vec<f32> = (1..=n).map(|i| i as f32).collect();
        let total: f32 = probs.iter().sum();
        probs.iter_mut().for_each(|p| *p /= total);
        let sk = SubSampleSketch::new(probs, 8);
        let dev = expectation_deviation(&sk, 6000, 2);
        assert!(dev < 0.3, "deviation {dev}");
    }

    #[test]
    fn gaussian_expectation_is_identity() {
        let sk = GaussianSketch::new(16, 32);
        let dev = expectation_deviation(&sk, 3000, 3);
        assert!(dev < 0.2, "deviation {dev}");
    }
}
