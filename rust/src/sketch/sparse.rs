//! Very sparse random projections (Li, Hastie & Church 2006) — §3.2's
//! fourth construction.  Entries are `sqrt(s/d)·{+1, 0, −1}` with
//! probabilities `{1/2s, 1−1/s, 1/2s}`; with `s = sqrt(n)` each column
//! touches only ~`n/√n` rows, so the sketch-apply is sub-linear in dense
//! multiplications while keeping `E[S Sᵀ] = I`.

use super::Sketch;
use crate::rng::Rng;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct VerySparseSketch {
    n: usize,
    d: usize,
    /// Sparsity parameter s (entry is non-zero w.p. 1/s).
    s: f32,
}

impl VerySparseSketch {
    /// Li et al.'s recommended `s = sqrt(n)`.
    pub fn new(n: usize, d: usize) -> Self {
        Self { n, d, s: (n as f32).sqrt().max(1.0) }
    }

    pub fn with_sparsity(n: usize, d: usize, s: f32) -> Self {
        assert!(s >= 1.0);
        Self { n, d, s }
    }

    /// Expected number of non-zeros per column.
    pub fn expected_nnz_per_col(&self) -> f32 {
        self.n as f32 / self.s
    }

    /// Sparse draw: per column, the (row, value) pairs.
    pub fn draw_sparse(&self, rng: &mut Rng) -> Vec<Vec<(usize, f32)>> {
        let p_nonzero = 1.0 / self.s;
        let val = (self.s / self.d as f32).sqrt();
        (0..self.d)
            .map(|_| {
                let mut col = Vec::new();
                for i in 0..self.n {
                    let u = rng.uniform();
                    if u < p_nonzero {
                        let sign = if u < p_nonzero * 0.5 { 1.0 } else { -1.0 };
                        col.push((i, sign * val));
                    }
                }
                col
            })
            .collect()
    }
}

impl Sketch for VerySparseSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn draw(&self, rng: &mut Rng) -> Matrix {
        let cols = self.draw_sparse(rng);
        let mut s = Matrix::zeros(self.n, self.d);
        for (k, col) in cols.iter().enumerate() {
            for &(i, v) in col {
                s.set(i, k, v);
            }
        }
        s
    }

    /// Sparse fast path for `B S`.
    fn sketch_right(&self, b: &Matrix, rng: &mut Rng) -> Matrix {
        let cols = self.draw_sparse(rng);
        let mut out = Matrix::zeros(b.rows(), self.d);
        for (k, col) in cols.iter().enumerate() {
            for &(i, v) in col {
                for r in 0..b.rows() {
                    let cur = out.get(r, k);
                    out.set(r, k, cur + b.get(r, i) * v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    #[test]
    fn sparsity_level_matches_parameter() {
        let sk = VerySparseSketch::with_sparsity(400, 8, 20.0);
        let mut rng = Rng::new(1);
        let cols = sk.draw_sparse(&mut rng);
        let total_nnz: usize = cols.iter().map(Vec::len).sum();
        let expect = 400.0 / 20.0 * 8.0;
        assert!(
            (total_nnz as f32 - expect).abs() < expect * 0.4,
            "nnz {total_nnz} vs expected {expect}"
        );
    }

    #[test]
    fn expectation_is_identity() {
        let sk = VerySparseSketch::with_sparsity(12, 16, 3.0);
        let dev = crate::sketch::expectation_deviation(&sk, 4000, 3);
        assert!(dev < 0.35, "E[SSᵀ] deviation {dev}");
    }

    #[test]
    fn sparse_apply_matches_dense() {
        let b = Matrix::from_fn(5, 30, |i, j| ((i * 30 + j) as f32 * 0.07).sin());
        let sk = VerySparseSketch::new(30, 6);
        let dense = {
            let mut rng = Rng::new(9);
            matmul(&b, &sk.draw(&mut rng))
        };
        let fast = {
            let mut rng = Rng::new(9);
            sk.sketch_right(&b, &mut rng)
        };
        assert!(dense.max_abs_diff(&fast) < 1e-4);
    }

    #[test]
    fn norm_preservation_on_average() {
        let n = 100;
        let sk = VerySparseSketch::new(n, 64);
        let x: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let xm = Matrix::from_vec(1, n, x.clone());
        let xn2: f32 = x.iter().map(|a| a * a).sum();
        let mut rng = Rng::new(5);
        let trials = 150;
        let mut est = 0.0f64;
        for _ in 0..trials {
            let proj = sk.sketch_right(&xm, &mut rng);
            est += proj.data().iter().map(|a| (a * a) as f64).sum::<f64>();
        }
        est /= trials as f64;
        assert!((est / xn2 as f64 - 1.0).abs() < 0.2, "ratio {}", est / xn2 as f64);
    }
}
