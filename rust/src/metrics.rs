//! Metrics substrate: timers, running statistics, and throughput counters
//! used by the training loop, the benches, and the serving example.

use std::time::{Duration, Instant};

/// Running mean/variance/min/max via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the error bars in Figure 1.
    pub fn std_err(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A percentile sketch backed by **full sample retention**: memory
/// grows without bound with the sample count, which is fine at bench
/// scale (a few thousand samples per run) but wrong for a long-running
/// server.  Serving paths use the constant-memory, mergeable
/// [`obs::Histo`](crate::obs::Histo) instead; this type stays for
/// offline benches that want exact interpolated quantiles.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp: NaN samples sort to the end instead of
            // panicking the percentile read
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
        let pos = q / 100.0 * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }
}

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Items-per-second throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { start: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.items as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_stats_match_closed_form() {
        let mut s = RunningStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic dataset is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.std_err() - s.std() / (8.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::default();
        for x in 1..=100 {
            p.push(x as f64);
        }
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((p.percentile(50.0) - 50.5).abs() < 1e-9);
        let p95 = p.percentile(95.0);
        assert!(p95 > 94.0 && p95 < 97.0);
    }

    #[test]
    fn percentile_of_empty_is_zero() {
        let mut p = Percentiles::default();
        assert_eq!(p.percentile(50.0), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_sort() {
        // regression: partial_cmp(..).unwrap() died on any NaN sample
        let mut p = Percentiles::default();
        for x in [3.0, f64::NAN, 1.0, 2.0] {
            p.push(x);
        }
        // NaN total-orders after every real number, so low quantiles
        // still read the finite samples
        assert_eq!(p.percentile(0.0), 1.0);
        assert!((p.percentile(50.0) - 2.5).abs() < 1e-9);
        assert!(p.percentile(100.0).is_nan());
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items(), 15);
        assert!(t.per_second() > 0.0);
    }
}
