//! Minimal JSON substrate (no serde offline): recursive-descent parser and
//! writer for the artifact manifests, experiment configs and report files.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (the manifests are ASCII).  Numbers parse to f64; helpers coerce to
//! the integer types call sites need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable diffs in golden files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|x| x.fract() == 0.0).map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.get(key)
    }

    /// `obj["a"]["b"][2]`-style path lookup; indices address arrays.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(o) => o.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Required-field helpers with readable errors.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field {key:?}"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {key:?}"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field {key:?}"))
    }

    // -------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // --------------------------------------------------------- serialization
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..w * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = &self.bytes[start..];
                    let len = utf8_len(s[0]);
                    if s.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&s[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.path(&["a", "2", "b"]), Some(&Json::Null));
        assert_eq!(v.req_str("c").unwrap(), "x");
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let doc = r#"{"k":[1,2.5,"s",false,null],"m":{"x":-1}}"#;
        let v = parse(doc).unwrap();
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        let back2 = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ≤ wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ≤ wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_formatting_stays_integral() {
        let v = Json::num(42.0);
        assert_eq!(v.to_string(), "42");
    }

    #[test]
    fn manifest_shaped_document() {
        // mirror of the aot.py manifest layout the runtime reads
        let doc = r#"{
          "method": "vmean",
          "params": [{"name": "embed/tok", "shape": [16, 64], "dtype": "float32"}],
          "params_bin": {"file": "vmean_params.bin", "f32_count": 1024},
          "train": {"inputs": [{"role": "param", "shape": [], "dtype": "float32"}]}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.req_str("method").unwrap(), "vmean");
        let p0 = &v.req_arr("params").unwrap()[0];
        assert_eq!(p0.req_str("name").unwrap(), "embed/tok");
        let shape: Vec<usize> =
            p0.req_arr("shape").unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![16, 64]);
    }
}
