//! Analytic FLOPs and activation-memory models (Tables 4 and 5).
//!
//! Table 5 reports the leading term of attention FLOPs at p=32, d=256;
//! [`leading_flops`] reproduces those expressions, and the `table5` bench
//! prints them alongside measured operation counts from the rust
//! implementations.  [`activation_memory`] is the per-example activation
//! footprint model behind Table 4's max-batch-size-under-16GB numbers.

/// Leading-term FLOPs for one attention head (the paper's Table 5).
/// `n` = sequence length, `d` = feature budget, `p` = head dim.
pub fn leading_flops(method: &str, n: u64, d: u64, p: u64) -> Option<u64> {
    Some(match method {
        "standard" | "standard_nodrop" => 2 * n * n * p,
        "bigbird" => 5 * n * d * p,
        "performer" => 3 * n * d * p,
        "nystromformer" => 4 * n * d * p,
        "linformer" => 4 * n * d * p,
        "informer" | "informer_mask" => 3 * n * d * p,
        "skeinformer" | "skein_uniform" | "skein_simple_norm" | "skein_no_psr"
        | "skein_no_norm" => 4 * n * d * p,
        "vmean" => n * p,
        // input-dependent (the paper excludes Reformer from Table 5)
        "reformer" => return None,
        "linformer_jlt" => 2 * n * n * p, // unreduced form is O(n²) by design
        _ => return None,
    })
}

/// The paper's Table-5 symbolic strings, for report rendering.
pub fn leading_flops_symbolic(method: &str) -> Option<&'static str> {
    Some(match method {
        "standard" | "standard_nodrop" => "2n^2p",
        "bigbird" => "5ndp",
        "performer" => "3ndp",
        "nystromformer" => "4ndp",
        "linformer" => "4ndp",
        "informer" | "informer_mask" => "3ndp",
        "skeinformer" => "4ndp",
        _ => return None,
    })
}

/// Per-example activation memory (bytes, f32) across the experimental
/// model's 2 layers × 2 heads — the driver of Table 4's batch sizes.
/// Counts the dominant transient: the score object each method
/// materialises, replicated per layer and head as autograd keeps them
/// alive for the backward pass.
pub fn activation_memory(method: &str, n: u64, d: u64, p: u64) -> u64 {
    // bytes per f32 × layers × heads × (forward + retained-for-backward)
    let f = 4 * 2 * 2 * 2;
    match method {
        // full n×n score matrix (dropout keeps a second copy)
        "standard" => 2 * n * n * f,
        "standard_nodrop" => n * n * f,
        "linformer_jlt" | "informer" | "informer_mask" => n * n * f / 2 + n * d * f,
        "vmean" => n * p * f,
        "bigbird" => 5 * n * d * f,
        "performer" | "linformer" | "nystromformer" => n * d * f,
        "reformer" => 2 * n * d * f,
        // skeinformer: (n,d) strip + (d,n) pilot strip
        m if m.starts_with("skein") => {
            let base = 2 * n * d * f;
            if m == "skein_no_norm" {
                // the no-row-norm ablation keeps an extra rescale buffer —
                // reproducing Table 4's smaller batch for that ablation
                base + n * d * f
            } else {
                base
            }
        }
        _ => n * n * f,
    }
}

/// Max batch size under a memory budget, in the power-of-two grid the
/// paper's gradient-accumulation protocol uses.
pub fn max_batch_size(method: &str, n: u64, d: u64, p: u64, budget_bytes: u64, cap: u64) -> u64 {
    let per = activation_memory(method, n, d, p).max(1);
    let raw = budget_bytes / per;
    // round down to a power of two, clamp to [1, cap]
    let mut b = 1u64;
    while b * 2 <= raw && b * 2 <= cap {
        b *= 2;
    }
    b.max(1)
}

/// Gradient-accumulation steps to reach an effective batch size.
pub fn accumulation_steps(effective_batch: u64, actual_batch: u64) -> u64 {
    effective_batch.div_ceil(actual_batch.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_expressions_at_paper_constants() {
        // p=32, d=256 as in Appendix A.2
        let (n, d, p) = (4096u64, 256, 32);
        assert_eq!(leading_flops("standard", n, d, p), Some(2 * n * n * p));
        assert_eq!(leading_flops("bigbird", n, d, p), Some(5 * n * d * p));
        assert_eq!(leading_flops("performer", n, d, p), Some(3 * n * d * p));
        assert_eq!(leading_flops("skeinformer", n, d, p), Some(4 * n * d * p));
        assert_eq!(leading_flops("informer", n, d, p), Some(3 * n * d * p));
        assert_eq!(leading_flops("reformer", n, d, p), None);
    }

    #[test]
    fn standard_dominates_at_long_n() {
        let (d, p) = (256, 32);
        for n in [1024u64, 2048, 4096] {
            let std = leading_flops("standard", n, d, p).unwrap();
            let skein = leading_flops("skeinformer", n, d, p).unwrap();
            assert!(std > skein, "n={n}");
        }
        // crossover: at n = 2d the standard method costs exactly 2·(4ndp)/4...
        // concretely standard/skein = n/(2d)
        let ratio = leading_flops("standard", 4096, 256, 32).unwrap() as f64
            / leading_flops("skeinformer", 4096, 256, 32).unwrap() as f64;
        assert!((ratio - 8.0).abs() < 1e-9); // 4096/(2·256) = 8
    }

    #[test]
    fn batch_size_ordering_matches_table4_shape() {
        // Table 4 (Text column, n=4096): standard 16, informer 16, skeinformer 64
        let n = 4096;
        let d = 256;
        let p = 32;
        let budget = 2u64 << 30;
        let b_std = max_batch_size("standard", n, d, p, budget, 512);
        let b_skein = max_batch_size("skeinformer", n, d, p, budget, 512);
        let b_inf = max_batch_size("informer", n, d, p, budget, 512);
        assert!(b_skein > b_std, "skein {b_skein} !> std {b_std}");
        assert!(b_skein > b_inf, "skein {b_skein} !> informer {b_inf}");
    }

    #[test]
    fn accumulation_steps_roundtrip() {
        assert_eq!(accumulation_steps(128, 16), 8);
        assert_eq!(accumulation_steps(128, 128), 1);
        assert_eq!(accumulation_steps(100, 32), 4);
    }

    #[test]
    fn symbolic_strings_cover_table5_rows() {
        for m in ["standard", "bigbird", "performer", "nystromformer", "linformer",
                  "informer", "skeinformer"] {
            assert!(leading_flops_symbolic(m).is_some(), "{m}");
        }
    }
}
