//! Bench harness substrate (no criterion offline).
//!
//! Benches are plain binaries (`[[bench]] harness = false`) that use
//! [`Bench`] for warmup + timed iterations with mean/std/percentile
//! reporting, and [`csv`] helpers to emit the figure series the paper
//! plots.  Designed so `cargo bench` output is self-describing.

use crate::metrics::{Percentiles, RunningStats};
use std::time::Instant;

/// Timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub measure_iters: u32,
    /// Hard cap on total measuring time (seconds) for slow cases.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 2, measure_iters: 10, max_seconds: 30.0 }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub p50_ms: f64,
    pub min_ms: f64,
    pub iters: u32,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms ± {:>8.3}  (p50 {:>9.3}, min {:>9.3}, n={})",
            self.name, self.mean_ms, self.std_ms, self.p50_ms, self.min_ms, self.iters
        )
    }
}

/// Run one benchmark case.
pub fn bench(name: &str, cfg: BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut stats = RunningStats::new();
    let mut pct = Percentiles::default();
    let deadline = Instant::now();
    let mut iters = 0u32;
    for _ in 0..cfg.measure_iters {
        let t0 = Instant::now();
        f();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        stats.push(ms);
        pct.push(ms);
        iters += 1;
        if deadline.elapsed().as_secs_f64() > cfg.max_seconds {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        mean_ms: stats.mean(),
        std_ms: stats.std(),
        p50_ms: pct.percentile(50.0),
        min_ms: stats.min(),
        iters,
    }
}

/// Write a CSV file under `reports/`, creating the directory.
pub fn write_csv(path: &str, header: &str, rows: &[String]) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::with_capacity(rows.len() * 32 + header.len() + 1);
    out.push_str(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// Render a fixed-width ASCII table (the paper-table reports).
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            out.push('+');
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench(
            "noop-ish",
            BenchConfig { warmup_iters: 1, measure_iters: 5, max_seconds: 5.0 },
            || {
                let mut x = 0u64;
                for i in 0..10_000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            },
        );
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 0.0);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
        assert!(!r.report_line().is_empty());
    }

    #[test]
    fn ascii_table_renders_aligned() {
        let t = ascii_table(
            &["model", "acc"],
            &[
                vec!["skeinformer".into(), "58.08".into()],
                vec!["standard".into(), "57.50".into()],
            ],
        );
        assert!(t.contains("| model"));
        assert!(t.contains("| skeinformer"));
        // all lines equal width
        let lens: std::collections::HashSet<usize> =
            t.lines().map(|l| l.len()).collect();
        assert_eq!(lens.len(), 1, "ragged table:\n{t}");
    }

    #[test]
    fn write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("skein_csv_test");
        let path = dir.join("x.csv");
        let p = path.to_str().unwrap();
        write_csv(p, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let body = std::fs::read_to_string(p).unwrap();
        assert_eq!(body, "a,b\n1,2\n3,4\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
