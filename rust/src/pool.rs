//! Scoped-thread parallelism substrate (no rayon/tokio offline).
//!
//! Two primitives cover every parallel site in the codebase:
//!
//! * [`parallel_row_blocks`] — split a row-major output buffer into
//!   contiguous row blocks and fill each on its own thread (matmul,
//!   attention row strips).
//! * [`parallel_map`] — map a function over items with a bounded worker
//!   count (Figure-1 trials, per-method experiment sweeps, the batched
//!   attention engine's per-head dispatch).  [`parallel_map_workers`] is
//!   the same primitive with an explicit worker cap — the batched engine's
//!   worker-count-invariance tests pin it to 1 vs [`worker_count`].
//!
//! Threads are spawned per call via `std::thread::scope`; for the coarse
//! work sizes here (≥ milliseconds per block) spawn overhead (~10 µs) is
//! noise, and the scope guarantees no detached threads survive a panic.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (≈ physical parallelism, capped).
pub fn worker_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Fill `out` (a `rows × cols` row-major buffer) by handing each worker a
/// contiguous block of rows. `f(range, block)` must fill `block` completely,
/// where `block` is the sub-slice for `range` (row indices).
pub fn parallel_row_blocks(
    out: &mut [f32],
    rows: usize,
    cols: usize,
    f: impl Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
) {
    assert_eq!(out.len(), rows * cols);
    let workers = worker_count().min(rows.max(1));
    if workers <= 1 || rows < 2 {
        f(0..rows, out);
        return;
    }
    let block = rows.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        while start < rows {
            let end = (start + block).min(rows);
            let (chunk, tail) = rest.split_at_mut((end - start) * cols);
            rest = tail;
            let fr = &f;
            let range = start..end;
            s.spawn(move || fr(range, chunk));
            start = end;
        }
    });
}

/// Map `f` over `items` in parallel, preserving order, with at most
/// [`worker_count`] threads. Work stealing via an atomic cursor keeps load
/// balanced when item costs vary (e.g. different attention methods).
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    parallel_map_workers(items, worker_count(), f)
}

/// [`parallel_map`] with an explicit worker cap.  Results are identical for
/// every cap (ordering and each item's computation are independent of the
/// schedule) — the batched attention engine's determinism tests rely on
/// comparing `workers = 1` against `workers = worker_count()` bitwise.
pub fn parallel_map_workers<T: Sync, R: Send>(
    items: &[T],
    workers: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            let slots_ptr = slots_ptr;
            s.spawn(move || {
                // force whole-struct capture (edition-2021 captures fields
                // at field granularity, which would capture the raw ptr)
                let slots_ptr = slots_ptr;
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&items[i]);
                    // SAFETY: each index i is claimed exactly once by exactly
                    // one worker (fetch_add), so writes never alias.
                    unsafe { *slots_ptr.0.add(i) = Some(r) };
                }
            });
        }
    });
    slots.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

struct SendPtr<T>(*mut T);
impl<T> Copy for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        Self(self.0)
    }
}
// SAFETY: see parallel_map — disjoint index ownership.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_blocks_cover_everything() {
        let rows = 37;
        let cols = 5;
        let mut out = vec![0.0f32; rows * cols];
        parallel_row_blocks(&mut out, rows, cols, |range, block| {
            for (bi, i) in range.enumerate() {
                for j in 0..cols {
                    block[bi * cols + j] = (i * cols + j) as f32;
                }
            }
        });
        for (idx, v) in out.iter().enumerate() {
            assert_eq!(*v, idx as f32);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..101).collect();
        let out = parallel_map(&items, |&x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, |x| *x).is_empty());
    }

    #[test]
    fn parallel_map_workers_invariant_to_cap() {
        let items: Vec<usize> = (0..53).collect();
        let one = parallel_map_workers(&items, 1, |&x| x * 3 + 1);
        for cap in [2, 3, worker_count(), 64] {
            let many = parallel_map_workers(&items, cap, |&x| x * 3 + 1);
            assert_eq!(one, many, "cap {cap} changed results");
        }
    }

    #[test]
    fn parallel_map_uneven_costs() {
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map(&items, |&x| {
            // simulate variable cost
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            acc.wrapping_add(x)
        });
        assert_eq!(out.len(), 32);
    }
}
