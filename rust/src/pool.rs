//! Persistent worker-pool parallelism substrate (no rayon/tokio offline).
//!
//! Two primitives cover every parallel site in the codebase:
//!
//! * [`parallel_row_blocks`] — split a row-major output buffer into
//!   contiguous row blocks and fill each on its own worker (matmul,
//!   attention row strips).
//! * [`parallel_map`] — map a function over items with a bounded worker
//!   count (Figure-1 trials, per-method experiment sweeps, the batched
//!   attention engine's per-head dispatch).  [`parallel_map_workers`] is
//!   the same primitive with an explicit worker cap — the batched engine's
//!   worker-count-invariance tests pin it to 1 vs [`worker_count`].
//!
//! Both primitives execute on one process-wide worker pool: long-lived
//! worker threads created lazily on first use, fed through a shared work
//! queue, torn down with [`shutdown_pool`] (and re-created on the next
//! parallel call).  Compared to the per-call `std::thread::scope` spawning
//! this replaced, the pool removes ~10–100 µs of spawn/join overhead per
//! call — noise for second-long blocks, but measurable for serving-shaped
//! workloads that issue thousands of small batched-attention grids (see
//! `benches/batched_throughput.rs`'s spawn-overhead probe).  Because the
//! workers are persistent, per-worker state is meaningful: the
//! [`take_scratch`]/[`recycle_scratch`] pair hands out reusable per-thread
//! f32 buffers so hot paths stop re-allocating head-sized slabs on every
//! task.
//!
//! **Blocking discipline (deadlock freedom).** A caller that submits a
//! batch of tasks never parks while work it depends on sits in the queue:
//! it *helps* — popping and running queued tasks until its own batch
//! completes.  Nested parallelism (a pool task that itself calls
//! [`parallel_row_blocks`], e.g. a per-head matmul) is therefore safe even
//! when every worker is busy: some thread always makes progress on the
//! leaf tasks.  Panics inside tasks are caught, forwarded to the
//! submitting caller (which re-raises after the whole batch has drained,
//! so no borrow outlives its use), and never kill a worker thread.
//!
//! **Determinism.** The pool never changes results: each task's
//! computation is a pure function of its inputs, independent of which
//! thread runs it or in what order (the batched attention engine's
//! bitwise worker-count invariance rests on this, and
//! `rust/tests/conformance.rs` pins it).
//!
//! Worker threads are pinned to the pool for its lifetime, not to cores —
//! CPU affinity is left to the deployment (`taskset`/cgroups), since std
//! has no portable affinity API.
//!
//! # Examples
//!
//! ```
//! use skeinformer::pool;
//!
//! let items: Vec<u64> = (0..64).collect();
//! let squares = pool::parallel_map(&items, |&x| x * x);
//! assert_eq!(squares[10], 100);
//!
//! // The pool can be resized or torn down between workloads; the next
//! // parallel call lazily re-initialises it.
//! pool::shutdown_pool();
//! assert_eq!(pool::parallel_map(&items, |&x| x + 1)[0], 1);
//! ```

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use by default: the logical CPU count
/// reported by `available_parallelism` (which honors cgroup quotas),
/// capped at 16.  [`pool_size`] reflects any [`set_pool_size`] override.
pub fn worker_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Upper bound on a configured pool size — a guard against typo'd
/// `--pool-size` values, far above any sensible CPU count here.
const MAX_POOL_SIZE: usize = 512;

/// Requested pool size; 0 means "default to [`worker_count`]".
static REQUESTED_SIZE: AtomicUsize = AtomicUsize::new(0);

/// The effective worker-thread count of the (current or next) pool.
pub fn pool_size() -> usize {
    match REQUESTED_SIZE.load(Ordering::Relaxed) {
        0 => worker_count(),
        n => n.min(MAX_POOL_SIZE),
    }
}

/// Set the pool's worker-thread count (`0` restores the
/// [`worker_count`] default).  If a pool of a different size is already
/// running it is shut down; the next parallel call re-initialises at the
/// new size.  Results never depend on the size — only throughput does.
///
/// Must not be called from inside a pool task (it joins worker threads).
pub fn set_pool_size(n: usize) {
    REQUESTED_SIZE.store(n, Ordering::Relaxed);
    let stale = {
        let mut guard = pool_slot().lock().expect("pool registry poisoned");
        let differs = guard.as_ref().is_some_and(|pool| pool.size != pool_size());
        if differs {
            guard.take()
        } else {
            None
        }
    };
    if let Some(pool) = stale {
        pool.stop();
    }
}

/// Shut down the process-wide pool: signal the workers, let them drain the
/// queue, and join them.  In-flight batches still complete (their
/// submitters help run any tasks the exiting workers leave behind).  The
/// next parallel call lazily re-creates the pool, so this is safe to call
/// between workloads — e.g. to measure cold-spawn cost, or to release the
/// threads before forking.
///
/// Must not be called from inside a pool task (it joins worker threads).
pub fn shutdown_pool() {
    let pool = pool_slot().lock().expect("pool registry poisoned").take();
    if let Some(pool) = pool {
        pool.stop();
    }
}

/// True once the process-wide pool has been created and not yet shut
/// down (diagnostics / tests).
pub fn pool_is_running() -> bool {
    pool_slot().lock().expect("pool registry poisoned").is_some()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the workers, submitters, and helpers: the work
/// queue plus the condvar that signals "queue non-empty or a batch
/// finished".
struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    signal: Condvar,
    shutdown: AtomicBool,
}

/// A running pool: the shared queue plus the worker join handles.
/// Worker threads are named `skein-pool-{i}`.
struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    fn spawn(size: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            signal: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("skein-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Signal shutdown and join.  Workers exit only once the queue is
    /// empty, so no queued task is ever dropped unrun.
    fn stop(self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = self.shared.queue.lock().expect("pool queue poisoned");
            self.shared.signal.notify_all();
        }
        for worker in self.workers {
            let _ = worker.join();
        }
    }
}

fn pool_slot() -> &'static Mutex<Option<WorkerPool>> {
    static POOL: Mutex<Option<WorkerPool>> = Mutex::new(None);
    &POOL
}

/// Shared queue handle, creating the pool on first use.
fn acquire() -> Arc<PoolShared> {
    let mut guard = pool_slot().lock().expect("pool registry poisoned");
    if guard.is_none() {
        *guard = Some(WorkerPool::spawn(pool_size()));
    }
    Arc::clone(&guard.as_ref().expect("pool just initialised").shared)
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut queue = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(job) = queue.pop_front() {
                    break job;
                }
                // Drain-then-exit: only leave on shutdown once the queue
                // is empty, so no batch is stranded.
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                queue = shared.signal.wait(queue).expect("pool queue poisoned");
            }
        };
        // Jobs are panic-wrapped by `run_batch`; nothing unwinds here.
        job();
    }
}

/// Completion latch for one submitted batch: outstanding-task count plus
/// the first panic payload (re-raised by the submitter once the batch has
/// fully drained).
struct Batch {
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Run a set of independent tasks to completion on the pool, helping from
/// the calling thread.  Blocks until every task has finished; re-raises
/// the first task panic after that point, so borrows inside the tasks
/// never outlive their use.
fn run_batch(tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let count = tasks.len();
    if count == 0 {
        return;
    }
    if count == 1 {
        // Inline: no queue round-trip, panics propagate natively.
        (tasks.into_iter().next().expect("one task"))();
        return;
    }

    let shared = acquire();
    let batch = Arc::new(Batch { remaining: AtomicUsize::new(count), panic: Mutex::new(None) });
    // Wrap every task outside the queue lock (boxing allocates; the lock
    // is the hottest in the process under many-small-batches load).
    let jobs: Vec<Job> = tasks
        .into_iter()
        .map(|task| {
            // SAFETY: the task may borrow from this stack frame.  We do
            // not return (or unwind) past the completion wait below until
            // `batch.remaining` reaches zero, i.e. until every task has
            // run to completion — the CompletionGuard enforces this even
            // if the wait itself fails, by aborting the process.  This is
            // the contract `std::thread::scope` provides, made explicit.
            let task: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(task)
            };
            let batch = Arc::clone(&batch);
            let shared = Arc::clone(&shared);
            Box::new(move || {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(task)) {
                    let mut slot = batch.panic.lock().expect("panic slot poisoned");
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                if batch.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    // Last task: wake the submitter.  Taking the queue
                    // lock orders this notify against the submitter's
                    // check-then-wait, so the wakeup cannot be missed.
                    // notify_all, not notify_one: the wakeup must not be
                    // swallowed by an idle worker.
                    let _guard = shared.queue.lock().expect("pool queue poisoned");
                    shared.signal.notify_all();
                }
            }) as Job
        })
        .collect();
    {
        // If this lock acquisition panics (poisoned), no job was queued
        // and no guard is armed yet, so unwinding here is safe.
        let mut queue = shared.queue.lock().expect("pool queue poisoned");
        queue.extend(jobs);
        // Wake at most one thread per queued job instead of the whole
        // pool — a woken thread always finds either a job to run or an
        // empty queue (someone else took it and will signal completion),
        // so no wakeup is load-bearing beyond these.
        for _ in 0..count.min(pool_size() + 1) {
            shared.signal.notify_one();
        }
    }

    // From here until the batch drains, the queue holds (or workers run)
    // jobs borrowing this frame; the guard keeps us from unwinding past
    // them no matter what.
    let mut guard = CompletionGuard { shared: &shared, batch: &batch, done: false };
    wait_batch(&shared, &batch);
    guard.done = true;
    drop(guard);

    let payload = batch.panic.lock().expect("panic slot poisoned").take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
}

/// Help-first wait: run queued tasks (ours or anyone's) instead of
/// parking while work is available.  Guarantees progress even if the
/// pool was shut down concurrently and zero workers remain.  Returns
/// once `batch.remaining` is zero.
fn wait_batch(shared: &PoolShared, batch: &Batch) {
    let mut queue = shared.queue.lock().expect("pool queue poisoned");
    loop {
        if batch.remaining.load(Ordering::Acquire) == 0 {
            break;
        }
        if let Some(job) = queue.pop_front() {
            drop(queue);
            job();
            queue = shared.queue.lock().expect("pool queue poisoned");
        } else {
            queue = shared.signal.wait(queue).expect("pool queue poisoned");
        }
    }
}

/// Armed between enqueue and batch completion: if `run_batch` unwinds
/// while tasks borrowing its frame may still be queued or running, the
/// guard re-enters the completion wait; if even that fails (poisoned pool
/// lock), it aborts the process rather than let a worker touch a dead
/// stack frame — the same last-resort `std::thread::scope` takes.
struct CompletionGuard<'a> {
    shared: &'a PoolShared,
    batch: &'a Batch,
    done: bool,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        let waited =
            catch_unwind(AssertUnwindSafe(|| wait_batch(self.shared, self.batch)));
        if waited.is_err() {
            std::process::abort();
        }
    }
}

/// Fill `out` (a `rows × cols` row-major buffer) by handing each worker a
/// contiguous block of rows. `f(range, block)` must fill `block` completely,
/// where `block` is the sub-slice for `range` (row indices).
///
/// # Panics
///
/// Panics if `out.len() != rows * cols`, or re-raises a panic from `f`
/// (after all blocks have drained).
pub fn parallel_row_blocks(
    out: &mut [f32],
    rows: usize,
    cols: usize,
    f: impl Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
) {
    assert_eq!(out.len(), rows * cols);
    let workers = pool_size().min(rows.max(1));
    if workers <= 1 || rows < 2 {
        f(0..rows, out);
        return;
    }
    let block = rows.div_ceil(workers);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
    let mut rest = out;
    let mut start = 0usize;
    while start < rows {
        let end = (start + block).min(rows);
        let (chunk, tail) = rest.split_at_mut((end - start) * cols);
        rest = tail;
        let fr = &f;
        tasks.push(Box::new(move || fr(start..end, chunk)));
        start = end;
    }
    run_batch(tasks);
}

/// Map `f` over `items` in parallel, preserving order, with at most
/// [`pool_size`] concurrent runners. Work stealing via an atomic cursor
/// keeps load balanced when item costs vary (e.g. different attention
/// methods).
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    parallel_map_workers(items, pool_size(), f)
}

/// [`parallel_map`] with an explicit worker cap.  Results are identical for
/// every cap (ordering and each item's computation are independent of the
/// schedule) — the batched attention engine's determinism tests rely on
/// comparing `workers = 1` against `workers = worker_count()` bitwise.
///
/// A cap above [`pool_size`] is honoured by queueing extra runners; they
/// execute as pool threads (plus the helping caller) free up.
pub fn parallel_map_workers<T: Sync, R: Send>(
    items: &[T],
    workers: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers <= 1 || pool_size() <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    let runner = |_: usize| {
        // force whole-struct capture (edition-2021 captures fields at
        // field granularity, which would capture the raw ptr)
        let slots_ptr = slots_ptr;
        loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let r = f(&items[i]);
            // SAFETY: each index i is claimed exactly once by exactly
            // one runner (fetch_add), so writes never alias.
            unsafe { *slots_ptr.0.add(i) = Some(r) };
        }
    };
    let runner = &runner;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
        (0..workers).map(|w| Box::new(move || runner(w)) as Box<dyn FnOnce() + Send + '_>).collect();
    run_batch(tasks);
    slots.into_iter().map(|x| x.expect("runner filled slot")).collect()
}

/// Raw-pointer wrapper that crosses task boundaries for *disjoint-index*
/// writes: each cooperating task derives a distinct element (or distinct
/// span) from the pointer, claims it exactly once, and the submitting
/// call does not return until every task completed — so writes never
/// alias and never outlive the borrow.  Shared by the crate's parallel
/// fan-out sites ([`parallel_map_workers`] here, the batched engine's
/// head writes, the server's per-head stream queries); every use site
/// carries its own SAFETY note restating the disjointness argument.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Copy for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
// SAFETY: disjoint index ownership, see the struct docs.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Per-worker scratch buffers
// ---------------------------------------------------------------------------

/// How many recycled buffers each thread keeps. The batched engine uses 4
/// per in-flight head (Q/K/V extraction + output staging) and the v2
/// attention methods route up to ~6 concurrent temporaries through
/// `AttnScratch` on top; headroom covers nested use.
const SCRATCH_KEEP: usize = 16;

thread_local! {
    static SCRATCH: std::cell::RefCell<Vec<Vec<f32>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Take a cleared, reusable f32 buffer with at least `capacity` reserved.
/// Buffers are per-thread: on the persistent pool workers they live for
/// the pool's lifetime, so steady-state hot paths stop allocating.
/// Return buffers with [`recycle_scratch`] when done; forgetting to is
/// safe (the buffer is simply freed).
///
/// Capacity is rounded up to a whole number of microkernel lanes
/// ([`crate::tensor::kernels::LANES`]) — the vector kernels use
/// unaligned loads so correctness never depends on this, but whole-lane
/// capacities make recycled buffers reusable across the slightly
/// different row lengths the attention scratch cycles through.
pub fn take_scratch(capacity: usize) -> Vec<f32> {
    let capacity = (capacity + (crate::tensor::kernels::LANES - 1))
        & !(crate::tensor::kernels::LANES - 1);
    let recycled = SCRATCH.with(|s| s.borrow_mut().pop());
    match recycled {
        Some(mut buf) => {
            buf.clear();
            buf.reserve(capacity);
            buf
        }
        None => Vec::with_capacity(capacity),
    }
}

/// Return a buffer taken with [`take_scratch`] to this thread's pool.
/// Keeps at most a small fixed number per thread; excess buffers are
/// dropped.
pub fn recycle_scratch(buf: Vec<f32>) {
    SCRATCH.with(|s| {
        let mut stash = s.borrow_mut();
        if stash.len() < SCRATCH_KEEP {
            stash.push(buf);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_blocks_cover_everything() {
        let rows = 37;
        let cols = 5;
        let mut out = vec![0.0f32; rows * cols];
        parallel_row_blocks(&mut out, rows, cols, |range, block| {
            for (bi, i) in range.enumerate() {
                for j in 0..cols {
                    block[bi * cols + j] = (i * cols + j) as f32;
                }
            }
        });
        for (idx, v) in out.iter().enumerate() {
            assert_eq!(*v, idx as f32);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..101).collect();
        let out = parallel_map(&items, |&x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, |x| *x).is_empty());
    }

    #[test]
    fn parallel_map_workers_invariant_to_cap() {
        let items: Vec<usize> = (0..53).collect();
        let one = parallel_map_workers(&items, 1, |&x| x * 3 + 1);
        for cap in [2, 3, worker_count(), 64] {
            let many = parallel_map_workers(&items, cap, |&x| x * 3 + 1);
            assert_eq!(one, many, "cap {cap} changed results");
        }
    }

    #[test]
    fn parallel_map_uneven_costs() {
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map(&items, |&x| {
            // simulate variable cost
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            acc.wrapping_add(x)
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn pool_persists_across_calls() {
        if pool_size() <= 1 {
            // single-core environment: every parallel call takes the
            // serial fast path and the pool is (correctly) never created
            return;
        }
        let items: Vec<usize> = (0..16).collect();
        let _ = parallel_map(&items, |&x| x);
        assert!(pool_is_running(), "first parallel call must initialise the pool");
        let _ = parallel_map(&items, |&x| x + 1);
        assert!(pool_is_running());
    }

    #[test]
    fn nested_parallelism_completes() {
        // a pool task that itself uses the pool (per-head matmul shape):
        // must finish rather than deadlock, with correct results.
        let items: Vec<usize> = (0..8).collect();
        let out = parallel_map(&items, |&x| {
            let inner: Vec<usize> = (0..32).collect();
            parallel_map_workers(&inner, 4, |&y| y * x).iter().sum::<usize>()
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * (31 * 32) / 2);
        }
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let items: Vec<usize> = (0..16).collect();
        let result = std::panic::catch_unwind(|| {
            parallel_map(&items, |&x| {
                if x == 7 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(result.is_err(), "task panic must reach the caller");
        // the pool must still work afterwards
        let out = parallel_map(&items, |&x| x + 1);
        assert_eq!(out[15], 16);
    }

    #[test]
    fn scratch_buffers_recycle_per_thread() {
        let mut buf = take_scratch(64);
        buf.extend_from_slice(&[1.0; 64]);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        recycle_scratch(buf);
        let again = take_scratch(16);
        assert!(again.is_empty(), "recycled scratch must come back cleared");
        assert!(again.capacity() >= cap.min(64));
        assert_eq!(again.as_ptr(), ptr, "same-thread take after recycle reuses the allocation");
        recycle_scratch(again);
    }
}
