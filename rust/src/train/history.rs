//! Training curves (Figure 2's validation-loss-vs-time series).

/// One evaluation point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistoryPoint {
    pub step: usize,
    pub seconds: f64,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_accuracy: f64,
}

/// An ordered series of evaluation points.
#[derive(Clone, Debug, Default)]
pub struct History {
    points: Vec<HistoryPoint>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, p: HistoryPoint) {
        debug_assert!(
            self.points.last().is_none_or(|last| p.step > last.step),
            "history must be monotone in step"
        );
        self.points.push(p);
    }

    pub fn points(&self) -> &[HistoryPoint] {
        &self.points
    }

    pub fn last(&self) -> Option<&HistoryPoint> {
        self.points.last()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Best (lowest) validation loss.
    pub fn best_val_loss(&self) -> Option<f64> {
        self.points.iter().map(|p| p.val_loss).min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Best validation accuracy.
    pub fn best_val_accuracy(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.val_accuracy)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Area-proxy for convergence speed: seconds until val loss first drops
    /// within `tol` of its eventual best (the "time to long-time limit"
    /// notion behind the paper's Fast Convergence claim).
    pub fn seconds_to_converge(&self, tol: f64) -> Option<f64> {
        let best = self.best_val_loss()?;
        self.points.iter().find(|p| p.val_loss <= best + tol).map(|p| p.seconds)
    }

    /// CSV rows for Figure-2 style plotting.
    pub fn csv_rows(&self, label: &str) -> Vec<String> {
        self.points
            .iter()
            .map(|p| {
                format!(
                    "{label},{},{:.3},{:.5},{:.5},{:.4}",
                    p.step, p.seconds, p.train_loss, p.val_loss, p.val_accuracy
                )
            })
            .collect()
    }

    pub const CSV_HEADER: &'static str = "method,step,seconds,train_loss,val_loss,val_accuracy";
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(step: usize, secs: f64, vl: f64, va: f64) -> HistoryPoint {
        HistoryPoint { step, seconds: secs, train_loss: vl + 0.1, val_loss: vl, val_accuracy: va }
    }

    #[test]
    fn best_metrics() {
        let mut h = History::new();
        h.push(mk(10, 1.0, 2.0, 0.3));
        h.push(mk(20, 2.0, 1.5, 0.5));
        h.push(mk(30, 3.0, 1.7, 0.45));
        assert_eq!(h.best_val_loss(), Some(1.5));
        assert_eq!(h.best_val_accuracy(), Some(0.5));
        assert_eq!(h.last().unwrap().step, 30);
    }

    #[test]
    fn convergence_time() {
        let mut h = History::new();
        h.push(mk(10, 1.0, 3.0, 0.2));
        h.push(mk(20, 2.0, 1.01, 0.4));
        h.push(mk(30, 3.0, 1.0, 0.4));
        // within 0.05 of best (1.0) first at t=2.0
        assert_eq!(h.seconds_to_converge(0.05), Some(2.0));
    }

    #[test]
    fn csv_format() {
        let mut h = History::new();
        h.push(mk(10, 1.0, 2.0, 0.3));
        let rows = h.csv_rows("skeinformer");
        assert_eq!(rows.len(), 1);
        assert!(rows[0].starts_with("skeinformer,10,"));
        assert_eq!(History::CSV_HEADER.split(',').count(), rows[0].split(',').count());
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn non_monotone_push_asserts() {
        let mut h = History::new();
        h.push(mk(10, 1.0, 1.0, 0.1));
        h.push(mk(5, 2.0, 1.0, 0.1));
    }
}
