//! Memory-budget batching plan (Table 4's protocol): given an effective
//! batch size and a memory budget, pick the largest actual batch the
//! method's activation footprint allows and make up the difference with
//! gradient accumulation.

use crate::flops;

/// The paper's effective batch sizes per task (Table 4 header).
pub fn effective_batch(task: &str) -> u64 {
    match task {
        "text" => 128,
        "listops" => 256,
        "retrieval" => 64,
        "pathfinder" => 512,
        "image" => 256,
        _ => 128,
    }
}

/// LRA sequence length per task (the paper's workloads: Text 4K chars,
/// ListOps 2K, Retrieval 2×4K, Pathfinder/Image 32×32 pixels).
pub fn task_seq_len(task: &str) -> u64 {
    match task {
        "text" => 4096,
        "listops" => 2048,
        "retrieval" => 8192,
        "pathfinder" => 1024,
        "image" => 1024,
        _ => 1024,
    }
}

/// A batching plan: actual batch + accumulation steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    pub actual_batch: u64,
    pub accum_steps: u64,
}

/// Compute the plan for a method/task at sequence length `n`, feature
/// budget `d`, head dim `p`, under `budget_bytes` of activation memory.
pub fn plan_batching(
    method: &str,
    task: &str,
    n: u64,
    d: u64,
    p: u64,
    budget_bytes: u64,
) -> BatchPlan {
    let eff = effective_batch(task);
    let actual = flops::max_batch_size(method, n, d, p, budget_bytes, eff);
    BatchPlan { actual_batch: actual, accum_steps: flops::accumulation_steps(eff, actual) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_batches_match_table4_header() {
        assert_eq!(effective_batch("text"), 128);
        assert_eq!(effective_batch("listops"), 256);
        assert_eq!(effective_batch("retrieval"), 64);
        assert_eq!(effective_batch("pathfinder"), 512);
        assert_eq!(effective_batch("image"), 256);
    }

    #[test]
    fn plan_shape_matches_table4() {
        // At LRA scale (text n=4096, 16 GB): skeinformer runs the full
        // effective batch (accum = 1-2) while standard needs heavy
        // accumulation — Table 4's shape.
        let budget = 16u64 << 30;
        let n = task_seq_len("text");
        let skein = plan_batching("skeinformer", "text", n, 256, 32, budget);
        let std = plan_batching("standard", "text", n, 256, 32, budget);
        assert!(skein.accum_steps <= 2, "{skein:?}");
        assert!(std.accum_steps >= 4, "{std:?}");
        assert_eq!(skein.actual_batch * skein.accum_steps % effective_batch("text"), 0);
    }

    #[test]
    fn task_lengths_match_lra() {
        assert_eq!(task_seq_len("text"), 4096);
        assert_eq!(task_seq_len("retrieval"), 8192);
        assert_eq!(task_seq_len("pathfinder"), 1024);
    }

    #[test]
    fn accumulation_covers_effective_batch() {
        for method in ["standard", "skeinformer", "informer", "linformer", "bigbird"] {
            for task in ["text", "listops", "retrieval", "pathfinder", "image"] {
                let plan = plan_batching(method, task, 1024, 256, 32, 1 << 30);
                assert!(
                    plan.actual_batch * plan.accum_steps >= effective_batch(task),
                    "{method}/{task}: {plan:?}"
                );
            }
        }
    }
}
