//! Checkpointing: persist/restore a training session's parameter and Adam
//! state, so long sweeps can resume and trained models can be served.
//!
//! Format: a JSON header (`<name>.ckpt.json`) with tensor names/shapes and
//! the step counter, plus a raw little-endian f32 blob (`<name>.ckpt.bin`)
//! holding params ‖ adam_m ‖ adam_v in manifest order — the same layout
//! discipline as the AOT params blob.

use crate::json::{parse, Json};
use anyhow::{Context, Result};
use std::path::Path;

/// A snapshot of training state, decoupled from the live session.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub method: String,
    pub step: u64,
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub params: Vec<Vec<f32>>,
    pub adam_m: Vec<Vec<f32>>,
    pub adam_v: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Write `<prefix>.ckpt.json` + `<prefix>.ckpt.bin`.
    pub fn save(&self, prefix: &Path) -> Result<()> {
        if let Some(dir) = prefix.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("step", Json::num(self.step as f64)),
            (
                "tensors",
                Json::arr(
                    self.names
                        .iter()
                        .zip(&self.shapes)
                        .map(|(n, s)| {
                            Json::obj(vec![
                                ("name", Json::str(n.clone())),
                                (
                                    "shape",
                                    Json::arr(s.iter().map(|&x| Json::num(x as f64)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path_json(prefix), header.to_pretty())?;
        let mut blob = Vec::new();
        for group in [&self.params, &self.adam_m, &self.adam_v] {
            for tensor in group.iter() {
                for x in tensor {
                    blob.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        std::fs::write(path_bin(prefix), blob)?;
        Ok(())
    }

    /// Load a checkpoint pair written by [`Checkpoint::save`].
    pub fn load(prefix: &Path) -> Result<Self> {
        let header = parse(&std::fs::read_to_string(path_json(prefix))?)
            .context("parsing checkpoint header")?;
        let method = header.req_str("method")?.to_string();
        let step = header.req_f64("step")? as u64;
        let tensors = header.req_arr("tensors")?;
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        for t in tensors {
            names.push(t.req_str("name")?.to_string());
            shapes.push(
                t.req_arr("shape")?
                    .iter()
                    .map(|x| x.as_usize().context("bad shape"))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        let sizes: Vec<usize> = shapes.iter().map(|s| s.iter().product()).collect();
        let total: usize = sizes.iter().sum();

        let bytes = std::fs::read(path_bin(prefix)).context("reading checkpoint blob")?;
        anyhow::ensure!(
            bytes.len() == total * 3 * 4,
            "blob size {} != 3×{total} f32",
            bytes.len()
        );
        let mut all = Vec::with_capacity(total * 3);
        for chunk in bytes.chunks_exact(4) {
            all.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        let split_group = |off: &mut usize| {
            let mut group = Vec::with_capacity(sizes.len());
            for &sz in &sizes {
                group.push(all[*off..*off + sz].to_vec());
                *off += sz;
            }
            group
        };
        let mut off = 0usize;
        let params = split_group(&mut off);
        let adam_m = split_group(&mut off);
        let adam_v = split_group(&mut off);
        Ok(Self { method, step, names, shapes, params, adam_m, adam_v })
    }
}

fn path_json(prefix: &Path) -> std::path::PathBuf {
    prefix.with_extension("ckpt.json")
}

fn path_bin(prefix: &Path) -> std::path::PathBuf {
    prefix.with_extension("ckpt.bin")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            method: "skeinformer".into(),
            step: 123,
            names: vec!["a/w".into(), "b/w".into()],
            shapes: vec![vec![2, 3], vec![4]],
            params: vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![7.0, 8.0, 9.0, 10.0]],
            adam_m: vec![vec![0.1; 6], vec![0.2; 4]],
            adam_v: vec![vec![0.3; 6], vec![0.4; 4]],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("skein_ckpt_test");
        let prefix = dir.join("run1");
        let ck = sample();
        ck.save(&prefix).unwrap();
        let back = Checkpoint::load(&prefix).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn truncated_blob_is_error() {
        let dir = std::env::temp_dir().join("skein_ckpt_trunc");
        let prefix = dir.join("run1");
        let ck = sample();
        ck.save(&prefix).unwrap();
        // truncate the blob
        let bin = prefix.with_extension("ckpt.bin");
        let bytes = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        assert!(Checkpoint::load(&prefix).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_files_are_errors() {
        assert!(Checkpoint::load(Path::new("/nonexistent/run")).is_err());
    }
}
