//! A live training session: compiled train/forward executables plus the
//! host-side copies of parameters and Adam state, advanced step by step.

use crate::config::ExperimentConfig;
use crate::data::Batch;
use crate::runtime::{literal_f32, literal_i32, scalar_f32, scalar_i32, ArtifactManifest,
                     Executable, Runtime};
use anyhow::{Context, Result};

pub struct TrainSession {
    manifest: ArtifactManifest,
    train_exe: Executable,
    fwd_exe: Executable,
    /// Host copies of params / adam m / adam v, in manifest order.
    params: Vec<Vec<f32>>,
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    step_no: u64,
    seed: i32,
    batch: usize,
    seq_len: usize,
    classes: usize,
    last_loss: f32,
    last_acc: f32,
}

impl TrainSession {
    /// Load artifacts for `cfg.method` and initialise state from the
    /// params blob.
    pub fn load(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Self> {
        let dir = std::path::Path::new(&cfg.artifacts_dir);
        let manifest = ArtifactManifest::load(dir, &cfg.method)?;
        // The artifact is shape-specialised; cross-check the config.
        let batch = manifest.cfg("batch")?;
        let seq_len = manifest.cfg("seq_len")?;
        let classes = manifest.cfg("classes")?;
        anyhow::ensure!(
            seq_len == cfg.model.seq_len,
            "artifact lowered at seq_len {seq_len}, config wants {}; re-run `make artifacts`",
            cfg.model.seq_len
        );
        let train_exe = rt.load_hlo(&manifest.train_path())?;
        let fwd_exe = rt.load_hlo(&manifest.forward_path())?;
        let params = manifest.load_initial_params()?;
        let adam_m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let adam_v = adam_m.clone();
        Ok(Self {
            manifest,
            train_exe,
            fwd_exe,
            params,
            adam_m,
            adam_v,
            step_no: 0,
            seed: cfg.train.seed as i32,
            batch,
            seq_len,
            classes,
            last_loss: f32::NAN,
            last_acc: f32::NAN,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn steps_taken(&self) -> u64 {
        self.step_no
    }

    pub fn method(&self) -> &str {
        &self.manifest.method
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Snapshot the full optimizer state for checkpointing.
    pub fn snapshot(&self) -> crate::train::Checkpoint {
        crate::train::Checkpoint {
            method: self.manifest.method.clone(),
            step: self.step_no,
            names: self.manifest.params.iter().map(|p| p.name.clone()).collect(),
            shapes: self.manifest.params.iter().map(|p| p.shape.clone()).collect(),
            params: self.params.clone(),
            adam_m: self.adam_m.clone(),
            adam_v: self.adam_v.clone(),
        }
    }

    /// Restore from a checkpoint (must match this session's method/shapes).
    pub fn restore(&mut self, ck: &crate::train::Checkpoint) -> Result<()> {
        anyhow::ensure!(ck.method == self.manifest.method, "checkpoint method mismatch");
        anyhow::ensure!(ck.params.len() == self.params.len(), "tensor count mismatch");
        for ((spec, ours), theirs) in
            self.manifest.params.iter().zip(&self.params).zip(&ck.params)
        {
            anyhow::ensure!(
                ours.len() == theirs.len(),
                "shape mismatch for {}", spec.name
            );
        }
        self.params = ck.params.clone();
        self.adam_m = ck.adam_m.clone();
        self.adam_v = ck.adam_v.clone();
        self.step_no = ck.step;
        Ok(())
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        let n = self.params.len();
        let mut lits = Vec::with_capacity(3 * n + 5);
        for (spec, buf) in self.manifest.params.iter().zip(&self.params) {
            lits.push(literal_f32(buf, &spec.shape)?);
        }
        for (spec, buf) in self.manifest.params.iter().zip(&self.adam_m) {
            lits.push(literal_f32(buf, &spec.shape)?);
        }
        for (spec, buf) in self.manifest.params.iter().zip(&self.adam_v) {
            lits.push(literal_f32(buf, &spec.shape)?);
        }
        Ok(lits)
    }

    /// One optimizer step on a batch; returns (loss, accuracy-on-batch).
    pub fn step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        anyhow::ensure!(batch.batch == self.batch, "batch size mismatch");
        anyhow::ensure!(batch.seq_len == self.seq_len, "seq_len mismatch");
        self.step_no += 1;
        let mut inputs = self.param_literals()?;
        inputs.push(scalar_f32(self.step_no as f32));
        inputs.push(literal_i32(&batch.tokens, &[self.batch, self.seq_len])?);
        inputs.push(literal_f32(&batch.mask, &[self.batch, self.seq_len])?);
        inputs.push(literal_i32(&batch.labels, &[self.batch])?);
        inputs.push(scalar_i32(self.seed));

        let outputs = self.train_exe.run(&inputs).context("train step")?;
        let n = self.params.len();
        anyhow::ensure!(
            outputs.len() == 3 * n + 2,
            "train step returned {} outputs, expected {}",
            outputs.len(),
            3 * n + 2
        );
        for (i, out) in outputs.iter().take(n).enumerate() {
            self.params[i] = out.to_vec::<f32>()?;
        }
        for (i, out) in outputs.iter().skip(n).take(n).enumerate() {
            self.adam_m[i] = out.to_vec::<f32>()?;
        }
        for (i, out) in outputs.iter().skip(2 * n).take(n).enumerate() {
            self.adam_v[i] = out.to_vec::<f32>()?;
        }
        self.last_loss = outputs[3 * n].get_first_element::<f32>()?;
        self.last_acc = outputs[3 * n + 1].get_first_element::<f32>()?;
        Ok((self.last_loss, self.last_acc))
    }

    /// Forward pass on one batch; returns logits (batch × classes).
    pub fn forward(&self, batch: &Batch) -> Result<Vec<f32>> {
        let mut inputs = Vec::with_capacity(self.params.len() + 3);
        for (spec, buf) in self.manifest.params.iter().zip(&self.params) {
            inputs.push(literal_f32(buf, &spec.shape)?);
        }
        inputs.push(literal_i32(&batch.tokens, &[self.batch, self.seq_len])?);
        inputs.push(literal_f32(&batch.mask, &[self.batch, self.seq_len])?);
        inputs.push(scalar_i32(self.seed));
        let outputs = self.fwd_exe.run(&inputs).context("forward")?;
        anyhow::ensure!(!outputs.is_empty(), "forward returned nothing");
        Ok(outputs[0].to_vec::<f32>()?)
    }

    /// Mean (val_loss, val_accuracy) over held-out batches.
    pub fn evaluate(&self, batches: &[Batch]) -> Result<(f64, f64)> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut loss_sum = 0.0f64;
        for batch in batches {
            let logits = self.forward(batch)?;
            for (b, &label) in batch.labels.iter().enumerate() {
                let row = &logits[b * self.classes..(b + 1) * self.classes];
                // softmax CE on host for the val loss
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let logsum =
                    max + row.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
                loss_sum += (logsum - row[label as usize]) as f64;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1)) // NaN-safe: diverged runs count as wrong
                    .map(|(i, _)| i)
                    .unwrap();
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok((loss_sum / total.max(1) as f64, correct as f64 / total.max(1) as f64))
    }
}
