//! Training loop: drives the AOT-compiled train-step artifact over the
//! synthetic LRA data streams, with the paper's protocol — Adam 1e-4,
//! validation-based early stopping ("if better performance is not observed
//! for 10 checking steps we stop"), and gradient accumulation when the
//! memory model caps the batch size (Table 4).

pub mod budget;
pub mod checkpoint;
pub mod history;
pub mod session;

pub use budget::plan_batching;
pub use checkpoint::Checkpoint;
pub use history::{History, HistoryPoint};
pub use session::TrainSession;

use crate::config::ExperimentConfig;
use crate::data::{Batcher, Task};
use crate::metrics::Timer;
use crate::rng::Rng;
use crate::runtime::Runtime;
use anyhow::Result;

/// Outcome of one training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub method: String,
    pub task: String,
    /// Optimizer steps taken before stopping.
    pub steps: usize,
    /// Best validation accuracy observed.
    pub best_accuracy: f64,
    /// Final (last-eval) accuracy.
    pub final_accuracy: f64,
    /// Wall-clock training seconds.
    pub seconds: f64,
    /// Milliseconds per optimizer step (mean).
    pub ms_per_step: f64,
    /// Gradient-accumulation steps used (Table 4's `accu`).
    pub grad_accum: usize,
    /// Loss/accuracy curve for Figure 2.
    pub history: History,
}

/// Train one (method, task) experiment end-to-end.
///
/// The runtime compiles `<method>_train.hlo.txt` and `<method>_fwd.hlo.txt`
/// once, then the loop is pure rust + PJRT.
pub fn run_experiment(rt: &Runtime, cfg: &ExperimentConfig) -> Result<TrainOutcome> {
    cfg.validate()?;
    let task = crate::data::by_name(&cfg.task, cfg.model.seq_len)
        .ok_or_else(|| anyhow::anyhow!("unknown task {}", cfg.task))?;
    let mut session = TrainSession::load(rt, cfg)?;

    let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
    let mut data_rng = Rng::new(cfg.train.seed).fold_in(0xDA7A);
    let mut eval_rng = Rng::new(cfg.train.seed).fold_in(0xE7A1);

    // fixed validation set (same examples at every eval, as a held-out split)
    let eval_batches: Vec<_> = (0..cfg.train.eval_examples.div_ceil(session.batch()))
        .map(|_| batcher.next_batch(&mut eval_rng))
        .collect();

    let mut history = History::new();
    let mut best = 0.0f64;
    let mut since_best = 0usize;
    let timer = Timer::start();
    let mut steps_done = 0usize;
    let mut step_ms_total = 0.0f64;

    for step in 1..=cfg.train.max_steps {
        let t0 = Timer::start();
        // gradient accumulation: the artifact applies Adam every call, so
        // accumulation is simulated by running `grad_accum` micro-batches
        // through the same step index (documented deviation: optimizer
        // state advances per micro-batch, matching small-batch SGD).
        let mut loss = 0.0f64;
        for _micro in 0..cfg.train.grad_accum {
            let batch = batcher.next_batch(&mut data_rng);
            let (l, _acc) = session.step(&batch)?;
            loss += l as f64;
        }
        loss /= cfg.train.grad_accum as f64;
        step_ms_total += t0.elapsed_ms();
        steps_done = step;

        if step % cfg.train.eval_every == 0 {
            let (val_loss, val_acc) = session.evaluate(&eval_batches)?;
            history.push(HistoryPoint {
                step,
                seconds: timer.elapsed().as_secs_f64(),
                train_loss: loss,
                val_loss,
                val_accuracy: val_acc,
            });
            if val_acc > best {
                best = val_acc;
                since_best = 0;
            } else {
                since_best += 1;
                if since_best >= cfg.train.patience {
                    break; // the paper's early-stopping rule
                }
            }
        }
    }

    let final_accuracy = history.last().map(|p| p.val_accuracy).unwrap_or(0.0);
    Ok(TrainOutcome {
        method: cfg.method.clone(),
        task: cfg.task.clone(),
        steps: steps_done,
        best_accuracy: best,
        final_accuracy,
        seconds: timer.elapsed().as_secs_f64(),
        ms_per_step: step_ms_total / steps_done.max(1) as f64,
        grad_accum: cfg.train.grad_accum,
        history,
    })
}

/// Quick accuracy of an untrained model ≈ chance; helper used by tests.
pub fn chance_accuracy(task: &dyn Task) -> f64 {
    1.0 / task.classes() as f64
}
