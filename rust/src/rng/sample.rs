//! Index sampling: the machinery behind the paper's sub-sampling sketches.
//!
//! * [`Rng::sample_with_replacement`] — uniform iid indices (pilot sampling,
//!   line 1 of Algorithm 1).
//! * [`Rng::categorical`] — one draw from a weighted distribution.
//! * [`Rng::weighted_without_replacement`] — Gumbel-top-k sampling without
//!   replacement under importance weights (line 5 of Algorithm 1).
//! * [`alias_table`] — O(1)-per-draw categorical sampling for the repeated
//!   draws in Definition 3.1's sub-sampling matrices.

use super::Rng;

impl Rng {
    /// `d` uniform indices in `[0, n)` with replacement.
    pub fn sample_with_replacement(&mut self, n: usize, d: usize) -> Vec<usize> {
        (0..d).map(|_| self.below(n)).collect()
    }

    /// One categorical draw from (unnormalised, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| w.max(0.0) as f64).sum();
        assert!(total > 0.0, "categorical with all-zero weights");
        let mut target = self.uniform() as f64 * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w.max(0.0) as f64;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1 // floating-point tail
    }

    /// Sample `d` distinct indices without replacement, with probability
    /// proportional to `weights`, via the Gumbel-top-k trick.  Zero-weight
    /// indices are never selected (padding masks rely on this).
    ///
    /// Allocating wrapper over
    /// [`weighted_without_replacement_into`](Self::weighted_without_replacement_into)
    /// — both draw the same RNG stream and select the same indices.
    pub fn weighted_without_replacement(&mut self, weights: &[f32], d: usize) -> Vec<usize> {
        let mut keyed = Vec::new();
        let mut out = Vec::new();
        self.weighted_without_replacement_into(weights, d, &mut keyed, &mut out);
        out
    }

    /// [`weighted_without_replacement`](Self::weighted_without_replacement)
    /// into caller-provided storage: `keyed` is the Gumbel-key workspace
    /// and `out` receives the selected indices (both cleared first), so a
    /// hot loop recycling the buffers (e.g. through
    /// `attention::AttnScratch`) draws O(d) samples with zero heap
    /// allocation in steady state.
    pub fn weighted_without_replacement_into(
        &mut self,
        weights: &[f32],
        d: usize,
        keyed: &mut Vec<(f32, usize)>,
        out: &mut Vec<usize>,
    ) {
        let d = d.min(weights.iter().filter(|w| **w > 0.0).count());
        keyed.clear();
        keyed.extend(
            weights
                .iter()
                .enumerate()
                .filter(|(_, w)| **w > 0.0)
                .map(|(i, &w)| (w.max(1e-30).ln() + self.gumbel(), i)),
        );
        // partial selection of the top d keys
        if d < keyed.len() {
            keyed.select_nth_unstable_by(d, |a, b| b.0.partial_cmp(&a.0).unwrap());
            keyed.truncate(d);
        }
        keyed.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        out.clear();
        out.extend(keyed.iter().map(|&(_, i)| i));
    }

    /// Uniform sample of `d` distinct indices (Floyd's algorithm).
    pub fn uniform_without_replacement(&mut self, n: usize, d: usize) -> Vec<usize> {
        let d = d.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(d);
        let mut out = Vec::with_capacity(d);
        for j in n - d..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

/// Walker alias table for O(1) categorical draws.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f32>,
    alias: Vec<usize>,
}

/// Build an alias table from (unnormalised) weights.
pub fn alias_table(weights: &[f32]) -> AliasTable {
    let n = weights.len();
    let total: f64 = weights.iter().map(|w| w.max(0.0) as f64).sum();
    assert!(total > 0.0 && n > 0, "alias_table needs positive mass");
    let scaled: Vec<f64> = weights.iter().map(|&w| w.max(0.0) as f64 * n as f64 / total).collect();
    let mut prob = vec![0.0f32; n];
    let mut alias = vec![0usize; n];
    let mut small: Vec<usize> = Vec::new();
    let mut large: Vec<usize> = Vec::new();
    let mut work = scaled;
    for (i, &w) in work.iter().enumerate() {
        if w < 1.0 {
            small.push(i);
        } else {
            large.push(i);
        }
    }
    // NB: pop only when BOTH stacks are non-empty — a combined
    // `while let (Some, Some) = (small.pop(), large.pop())` would pop and
    // silently discard the last element of the non-empty stack.
    while !small.is_empty() && !large.is_empty() {
        let s = small.pop().unwrap();
        let l = large.pop().unwrap();
        prob[s] = work[s] as f32;
        alias[s] = l;
        work[l] = (work[l] + work[s]) - 1.0;
        if work[l] < 1.0 {
            small.push(l);
        } else {
            large.push(l);
        }
    }
    for i in small.into_iter().chain(large) {
        prob[i] = 1.0;
        alias[i] = i;
    }
    AliasTable { prob, alias }
}

impl AliasTable {
    /// One O(1) categorical draw.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_replacement_in_range() {
        let mut rng = Rng::new(1);
        let idx = rng.sample_with_replacement(10, 100);
        assert_eq!(idx.len(), 100);
        assert!(idx.iter().all(|&i| i < 10));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(2);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn without_replacement_distinct_and_weighted() {
        let mut rng = Rng::new(3);
        let mut w = vec![1.0f32; 100];
        w[7] = 1000.0; // index 7 should essentially always be selected
        let mut hit7 = 0;
        for _ in 0..200 {
            let sel = rng.weighted_without_replacement(&w, 10);
            assert_eq!(sel.len(), 10);
            let set: std::collections::HashSet<_> = sel.iter().collect();
            assert_eq!(set.len(), 10, "duplicates in {sel:?}");
            if sel.contains(&7) {
                hit7 += 1;
            }
        }
        assert!(hit7 > 195, "heavy index selected only {hit7}/200");
    }

    #[test]
    fn without_replacement_skips_zero_weights() {
        let mut rng = Rng::new(4);
        let mut w = vec![0.0f32; 50];
        for item in w.iter_mut().take(20) {
            *item = 1.0;
        }
        for _ in 0..50 {
            let sel = rng.weighted_without_replacement(&w, 10);
            assert!(sel.iter().all(|&i| i < 20), "picked padded index: {sel:?}");
        }
    }

    #[test]
    fn into_variant_matches_allocating_exactly() {
        // includes zero weights, so the zero-skip path is exercised too
        let w: Vec<f32> = (0..40).map(|i| ((i * 7 + 3) % 11) as f32).collect();
        let mut a = Rng::new(12);
        let mut b = Rng::new(12);
        let want = a.weighted_without_replacement(&w, 10);
        // dirty reused workspaces must not affect the result
        let mut keyed = vec![(0.5f32, 99usize); 3];
        let mut got = vec![5usize; 7];
        b.weighted_without_replacement_into(&w, 10, &mut keyed, &mut got);
        assert_eq!(got, want);
        assert_eq!(a.next_u64(), b.next_u64(), "streams must stay in lockstep");
    }

    #[test]
    fn without_replacement_caps_at_support() {
        let mut rng = Rng::new(5);
        let w = [1.0f32, 0.0, 2.0];
        let sel = rng.weighted_without_replacement(&w, 10);
        assert_eq!(sel.len(), 2);
    }

    #[test]
    fn uniform_without_replacement_distinct() {
        let mut rng = Rng::new(6);
        let sel = rng.uniform_without_replacement(30, 30);
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn alias_table_matches_weights() {
        let mut rng = Rng::new(7);
        let w = [1.0f32, 2.0, 7.0];
        let table = alias_table(&w);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[table.draw(&mut rng)] += 1;
        }
        for (i, &wi) in w.iter().enumerate() {
            let expect = wi as f64 / 10.0;
            let got = counts[i] as f64 / n as f64;
            assert!((got - expect).abs() < 0.01, "i={i} got {got} expect {expect}");
        }
    }
}
