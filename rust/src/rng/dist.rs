//! Continuous distributions on top of [`Rng`].

use super::Rng;

impl Rng {
    /// Standard normal via Box-Muller (one value per call; the pair's
    /// second half is discarded to keep the stream stateless and
    /// fold-in-friendly).
    pub fn normal(&mut self) -> f32 {
        // avoid log(0)
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Standard Gumbel — the perturbation behind Gumbel-top-k sampling
    /// without replacement (matches the jax-side trick bit-for-concept).
    pub fn gumbel(&mut self) -> f32 {
        let u = self.uniform().clamp(1e-20, 1.0 - 1e-7);
        -(-u.ln()).ln()
    }

    /// Exponential(1).
    pub fn exponential(&mut self) -> f32 {
        -self.uniform().max(1e-12).ln()
    }

    /// Fill a buffer with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        out.iter_mut().for_each(|x| *x = self.normal());
    }

    /// Fill with iid uniform [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        out.iter_mut().for_each(|x| *x = self.uniform_range(lo, hi));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut rng = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.gumbel() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exponential_mean_one() {
        let mut rng = Rng::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential() as f64).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn fill_helpers() {
        let mut rng = Rng::new(19);
        let mut buf = vec![0.0f32; 64];
        rng.fill_uniform(&mut buf, 2.0, 3.0);
        assert!(buf.iter().all(|x| (2.0..3.0).contains(x)));
        rng.fill_normal(&mut buf);
        assert!(buf.iter().any(|x| *x < 0.0) && buf.iter().any(|x| *x > 0.0));
    }
}
