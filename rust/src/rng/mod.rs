//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`Rng`] is a PCG-XSH-RR 64/32 generator seeded through SplitMix64, with
//! `split`-style sub-stream derivation so experiments can hand independent
//! streams to threads/trials reproducibly — the same discipline jax's
//! `PRNGKey`/`fold_in` gives the python layer.
//!
//! Distributions live in [`dist`]; weighted sampling (the paper's
//! sub-sampling matrices) in [`sample`].

pub mod dist;
pub mod sample;

// distributions are inherent impls on Rng (see dist.rs)
pub use sample::*;

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output. Small, fast, and passes
/// the statistical tests that matter at our sample sizes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

/// Mix a label into a base seed, returning a decorrelated derived seed —
/// the same SplitMix64 discipline [`Rng::fold_in`] uses, as a plain u64
/// function.  For handing disjoint seed *families* to subsystems that
/// themselves XOR small indices into their seeds (e.g. the batched
/// attention engine's per-head derivation): XOR-composing labels would
/// collide, mixing does not.
pub fn mix(base: u64, data: u64) -> u64 {
    let mut s = base ^ data.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    splitmix64(&mut s)
}

/// SplitMix64 — used for seeding and stream derivation.
#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator; different seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1; // stream selector must be odd
        let mut rng = Self { state, inc };
        rng.next_u32(); // warm up
        rng
    }

    /// Derive an independent sub-stream (like jax `fold_in`).
    pub fn fold_in(&self, data: u64) -> Self {
        let mut s = self.state ^ data.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Self { state, inc };
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection method — unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let hi = ((x as u128 * n as u128) >> 64) as u64;
            let lo = x.wrapping_mul(n);
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn fold_in_derives_independent_stream() {
        let base = Rng::new(42);
        let mut c1 = base.fold_in(0);
        let mut c2 = base.fold_in(1);
        let same = (0..32).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect / 10) as i64);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
