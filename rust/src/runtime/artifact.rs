//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! rust runtime.  A manifest records the input ordering (params, adam
//! state, batch tensors, scalars), output layout, the model config the
//! artifact was lowered with, and the initial-parameter blob.

use crate::json::{parse, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub role: String,
    pub name: Option<String>,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|x| x.as_usize().context("bad shape entry"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            role: j.req_str("role")?.to_string(),
            name: j.get("name").and_then(Json::as_str).map(str::to_string),
            shape,
            dtype: j.req_str("dtype")?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `<method>_manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub method: String,
    pub dir: PathBuf,
    /// Model config fields (vocab, seq_len, batch, classes, ...).
    pub config: std::collections::BTreeMap<String, f64>,
    pub params: Vec<ParamSpec>,
    pub params_bin_file: String,
    pub params_f32_count: usize,
    pub train_file: String,
    pub train_inputs: Vec<IoSpec>,
    pub forward_file: String,
    pub forward_inputs: Vec<IoSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/<method>_manifest.json`.
    pub fn load(dir: &Path, method: &str) -> Result<Self> {
        let path = dir.join(format!("{method}_manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let j = parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let method = j.req_str("method")?.to_string();
        let mut config = std::collections::BTreeMap::new();
        if let Some(cfg) = j.get("config").and_then(Json::as_obj) {
            for (k, v) in cfg {
                if let Some(x) = v.as_f64() {
                    config.insert(k.clone(), x);
                }
            }
        }
        let params = j
            .req_arr("params")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req_arr("shape")?
                        .iter()
                        .map(|x| x.as_usize().context("bad param shape"))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let pb = j.get("params_bin").context("missing params_bin")?;
        let train = j.get("train").context("missing train section")?;
        let fwd = j.get("forward").context("missing forward section")?;
        let train_inputs = train
            .req_arr("inputs")?
            .iter()
            .map(IoSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let forward_inputs = fwd
            .req_arr("inputs")?
            .iter()
            .map(IoSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let man = Self {
            method,
            dir: dir.to_path_buf(),
            config,
            params,
            params_bin_file: pb.req_str("file")?.to_string(),
            params_f32_count: pb.req_usize("f32_count")?,
            train_file: train.req_str("file")?.to_string(),
            train_inputs,
            forward_file: fwd.req_str("file")?.to_string(),
            forward_inputs,
        };
        man.validate()?;
        Ok(man)
    }

    /// Internal consistency checks (the contract tests in python mirror
    /// these from the producer side).
    pub fn validate(&self) -> Result<()> {
        let n = self.params.len();
        anyhow::ensure!(n > 0, "no parameters");
        let total: usize = self.params.iter().map(ParamSpec::elements).sum();
        anyhow::ensure!(
            total == self.params_f32_count,
            "params_bin count {} != sum of param elements {}",
            self.params_f32_count,
            total
        );
        // train inputs: params*N, adam_m*N, adam_v*N, step, tokens, mask, labels, seed
        anyhow::ensure!(
            self.train_inputs.len() == 3 * n + 5,
            "train inputs {} != 3*{n}+5",
            self.train_inputs.len()
        );
        for (i, spec) in self.train_inputs.iter().take(n).enumerate() {
            anyhow::ensure!(spec.role == "param", "input {i} role {}", spec.role);
            anyhow::ensure!(
                spec.name.as_deref() == Some(self.params[i].name.as_str()),
                "param order mismatch at {i}"
            );
        }
        let tail: Vec<&str> =
            self.train_inputs[3 * n..].iter().map(|s| s.role.as_str()).collect();
        anyhow::ensure!(
            tail == ["step", "tokens", "mask", "labels", "seed"],
            "unexpected tail roles {tail:?}"
        );
        // names sorted == canonical order
        let mut sorted = self.params.clone();
        sorted.sort_by(|a, b| a.name.cmp(&b.name));
        anyhow::ensure!(
            sorted.iter().map(|p| &p.name).eq(self.params.iter().map(|p| &p.name)),
            "params not in canonical (sorted) order"
        );
        Ok(())
    }

    pub fn train_path(&self) -> PathBuf {
        self.dir.join(&self.train_file)
    }

    pub fn forward_path(&self) -> PathBuf {
        self.dir.join(&self.forward_file)
    }

    /// Config accessors (lowered-with values).
    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|x| *x as usize)
            .with_context(|| format!("manifest config missing {key}"))
    }

    /// Load the initial parameters from the binary blob, split per tensor
    /// in manifest order.
    pub fn load_initial_params(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&self.params_bin_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading params blob {path:?}"))?;
        anyhow::ensure!(
            bytes.len() == self.params_f32_count * 4,
            "blob size {} != {} f32",
            bytes.len(),
            self.params_f32_count
        );
        let mut all = Vec::with_capacity(self.params_f32_count);
        for chunk in bytes.chunks_exact(4) {
            all.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0usize;
        for p in &self.params {
            let n = p.elements();
            out.push(all[off..off + n].to_vec());
            off += n;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json(n_extra_tail: bool) -> String {
        let tail = if n_extra_tail {
            r#"{"role": "step", "shape": [], "dtype": "float32"},
               {"role": "tokens", "shape": [2, 8], "dtype": "int32"},
               {"role": "mask", "shape": [2, 8], "dtype": "float32"},
               {"role": "labels", "shape": [2], "dtype": "int32"},
               {"role": "seed", "shape": [], "dtype": "int32"}"#
        } else {
            r#"{"role": "step", "shape": [], "dtype": "float32"}"#
        };
        format!(
            r#"{{
            "method": "vmean",
            "config": {{"batch": 2, "seq_len": 8, "classes": 3}},
            "params": [
               {{"name": "a/w", "shape": [2, 3], "dtype": "float32"}},
               {{"name": "b/w", "shape": [4], "dtype": "float32"}}
            ],
            "params_bin": {{"file": "p.bin", "f32_count": 10}},
            "train": {{
              "file": "t.hlo.txt",
              "inputs": [
                {{"role": "param", "name": "a/w", "shape": [2,3], "dtype": "float32"}},
                {{"role": "param", "name": "b/w", "shape": [4], "dtype": "float32"}},
                {{"role": "adam_m", "name": "a/w", "shape": [2,3], "dtype": "float32"}},
                {{"role": "adam_m", "name": "b/w", "shape": [4], "dtype": "float32"}},
                {{"role": "adam_v", "name": "a/w", "shape": [2,3], "dtype": "float32"}},
                {{"role": "adam_v", "name": "b/w", "shape": [4], "dtype": "float32"}},
                {tail}
              ],
              "outputs": {{"n_params": 2, "extra": ["loss", "acc"]}}
            }},
            "forward": {{
              "file": "f.hlo.txt",
              "inputs": [{{"role": "tokens", "shape": [2,8], "dtype": "int32"}}],
              "outputs": {{"logits": [2, 3]}}
            }}
          }}"#
        )
    }

    #[test]
    fn parses_and_validates_well_formed_manifest() {
        let j = parse(&fake_manifest_json(true)).unwrap();
        let man = ArtifactManifest::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(man.method, "vmean");
        assert_eq!(man.params.len(), 2);
        assert_eq!(man.cfg("batch").unwrap(), 2);
        assert_eq!(man.train_path(), PathBuf::from("/tmp/a/t.hlo.txt"));
        assert_eq!(man.params[0].elements(), 6);
    }

    #[test]
    fn rejects_truncated_inputs() {
        let j = parse(&fake_manifest_json(false)).unwrap();
        assert!(ArtifactManifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn params_blob_split() {
        let dir = std::env::temp_dir().join("skein_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut blob = Vec::new();
        for i in 0..10 {
            blob.extend_from_slice(&(i as f32).to_le_bytes());
        }
        std::fs::write(dir.join("p.bin"), blob).unwrap();
        let j = parse(&fake_manifest_json(true)).unwrap();
        let man = ArtifactManifest::from_json(&j, &dir).unwrap();
        let params = man.load_initial_params().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(params[1], vec![6.0, 7.0, 8.0, 9.0]);
        let _ = std::fs::remove_dir_all(dir);
    }
}
