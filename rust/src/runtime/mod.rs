//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the rust request path (python never runs here).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py`).

pub mod artifact;
pub mod literal;

pub use artifact::{ArtifactManifest, IoSpec, ParamSpec};
pub use literal::{literal_f32, literal_i32, scalar_f32, scalar_i32, to_vec_f32};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU device handle. One per thread of execution — the underlying
/// client is `Rc`-based (not `Send`), matching PJRT's threading model.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it for this device.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path_str}"))?;
        Ok(Executable { exe, source: path.to_path_buf() })
    }
}

/// A compiled XLA executable (one entry computation, tuple output — the
/// `return_tuple=True` convention from aot.py).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    source: PathBuf,
}

impl Executable {
    pub fn source(&self) -> &Path {
        &self.source
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outputs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {:?}", self.source))?;
        let mut result = outputs
            .first()
            .and_then(|r| r.first())
            .context("executable returned no outputs")?
            .to_literal_sync()
            .context("fetching output literal")?;
        result.decompose_tuple().context("decomposing output tuple")
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/ (they
    // depend on `make artifacts` having run); here we only test pure logic.
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_hlo(Path::new("/nonexistent/x.hlo.txt"));
        assert!(err.is_err());
    }

    #[test]
    fn runtime_reports_cpu_platform() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert!(rt.device_count() >= 1);
    }
}
