//! Host ↔ `xla::Literal` packing helpers.

use anyhow::{Context, Result};

/// An f32 literal of the given shape from a row-major buffer.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let count: usize = shape.iter().product();
    anyhow::ensure!(count == data.len(), "shape {shape:?} != data len {}", data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).context("reshaping f32 literal")
}

/// An i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let count: usize = shape.iter().product();
    anyhow::ensure!(count == data.len(), "shape {shape:?} != data len {}", data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).context("reshaping i32 literal")
}

/// Rank-0 scalars.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Copy a literal back to a host f32 vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("reading f32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![7i32, -1, 0, 3];
        let lit = literal_i32(&data, &[4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn scalars_are_rank_zero() {
        let s = scalar_f32(2.5);
        assert_eq!(s.element_count(), 1);
        let shape = s.array_shape().unwrap();
        assert_eq!(shape.dims().len(), 0);
    }

    #[test]
    fn shape_mismatch_is_error() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
